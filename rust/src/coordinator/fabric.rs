//! The **peer fabric** — N cooperating cache boxes behind one client.
//!
//! The paper's topology has exactly one middle node; this module
//! generalises the client side to a fleet of them.  Each [`Peer`] bundles
//! everything one cache box costs a client: a **pooled** [`KvClient`]
//! connection (redialed only after an error, never per-operation), a
//! per-peer link [`Shaper`], the peer's own [`LocalCatalog`] (merged by
//! that peer's `CatalogSync` loop, so a Bloom hit names *which* box claims
//! a range), and a [`PeerLedger`] of bytes and time attributable to that
//! box.
//!
//! [`fetch_prefix_multi`] is the fabric's download engine.  Given the set
//! of peers claiming a matched range, it:
//!
//! 1. acquires the entry **head** (header + chunk index) from the first
//!    live claimer via the server-push `GETCHUNKS` command — with a single
//!    claimer the same request already carries every matched chunk, so the
//!    deflated path's old extra head round trip is gone and each chunk
//!    still decodes the moment its bytes land;
//! 2. splits the remaining whole chunks into goodput-weighted contiguous
//!    stripes ([`PeerPlanner::split_chunks`]) and drives **one reply
//!    stream per peer concurrently** (scoped threads, one pipelined
//!    `GETRANGE` batch each), every arrival fed straight into a shared
//!    [`StateAssembler`] under a mutex — aggregate goodput scales with
//!    peer count because each peer's modelled wire time elapses in its own
//!    thread;
//! 3. on a mid-stream share failure (dead box, short/corrupt reply),
//!    re-plans the orphaned chunks onto the surviving peers
//!    ([`PeerPlanner::reassign`]) and fetches them there — a peer death
//!    mid-trace degrades throughput, never correctness, because every
//!    chunk re-verifies against the head peer's crc index no matter which
//!    box served it.
//!
//! With a [`LocalRecompute`] feeder attached (`--plan chunk` on a paced
//! device), the fetch additionally consults the per-chunk cost model
//! (`coordinator::plan`): the exact stored chunk lengths from the verified
//! index are priced against the device's prefill rate, and the resulting
//! split plan recomputes the cheap leading chunks locally (the feeder runs
//! on the calling thread, overlapping the share threads' modelled wire
//! time) while the expensive suffix is striped across peers as before.
//! Orphaned chunks can then be re-planned onto *either* a survivor or the
//! local feeder — whichever the model says is cheaper — so a fetch
//! survives even the death of the last claimer, and a single corrupt
//! chunk degrades to one chunk of recompute instead of a full-blob
//! fallback.
//!
//! Anything unrecoverable returns `None` and the caller falls back to a
//! full-blob download ([`fetch_full_entry`]) and then to local prefill —
//! the same never-restore-questionable-bytes ladder as the single-box
//! system.

use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::catalog::LocalCatalog;
use crate::coordinator::membership::{
    classify_io_err, DeadlineBudget, HealthSink, IndirectProbe, Membership, Outcome,
    PeerHealth,
};
use crate::coordinator::plan::{cost_of, plan_split, ChunkCost, ChunkSource, LinkCost};
use crate::coordinator::policy::PeerPlanner;
use crate::coordinator::sync::CatalogSync;
use crate::kvstore::client::{getrange_req, ChunksReply, StreamingReplies};
use crate::kvstore::resp::{request_shared, Value};
use crate::kvstore::KvClient;
use crate::log_debug;
use crate::metrics::{PeerLedger, Phase};
use crate::model::state::{BlobLayout, ChunkEntry, ChunkVerifier, KvState, StateAssembler};
use crate::netsim::{apply_byte_fault, LinkModel, Shaper, StreamSession};
use crate::sketch::SketchTable;
use crate::util::bytes::SharedBytes;

/// One cache-box peer in the client configuration.
#[derive(Debug, Clone)]
pub struct PeerConfig {
    /// Cache-box address (`host:port`).
    pub addr: String,
    /// Per-peer link model; `None` inherits the client's default link
    /// (`EdgeClientConfig::link`), so homogeneous fleets configure one
    /// link once and heterogeneous ones override per box.
    pub link: Option<LinkModel>,
    /// Relative placement weight for rendezvous-hash ownership (capacity
    /// hint: a weight-2 box owns ~2x the keys of a weight-1 box).  Ignored
    /// by the load-probing p2c policy.  1.0 = uniform.
    pub weight: f64,
    /// Socket deadlines for this peer's pooled connections: `connect`
    /// bounds the dial, `op` arms read/write timeouts so a *stalled*
    /// (accepted-but-silent) box costs at most one budget, never a hang.
    /// `None` keeps the historical blocking behavior.
    pub deadline: Option<DeadlineBudget>,
    /// Adaptive-deadline multiplier `k`: before each sized operation the
    /// fabric re-arms the op timeout at `k ×` the link model's expected
    /// transfer time (floored by `deadline.op`, doubled while the peer is
    /// `Suspect`), so a 270 ms-RTT Wi-Fi peer and a loopback peer stop
    /// sharing one stall threshold.  `<= 0` keeps the static budget.
    pub deadline_k: f64,
    /// Canonical fleet identity of this box for gossip digests and relayed
    /// probes.  `None` means `addr` *is* the identity; they diverge when
    /// the client reaches the box through an interposer (the chaos-proxy
    /// harness) but the fleet-wide health view must name the real box.
    pub gossip_addr: Option<String>,
}

impl PeerConfig {
    pub fn new(addr: impl Into<String>) -> Self {
        PeerConfig {
            addr: addr.into(),
            link: None,
            weight: 1.0,
            deadline: None,
            deadline_k: 0.0,
            gossip_addr: None,
        }
    }

    pub fn with_link(addr: impl Into<String>, link: LinkModel) -> Self {
        PeerConfig { link: Some(link), ..Self::new(addr) }
    }

    pub fn with_deadline(mut self, deadline: DeadlineBudget) -> Self {
        self.deadline = Some(deadline);
        self
    }

    /// Enable adaptive per-op deadlines at multiplier `k` (see
    /// [`PeerConfig::deadline_k`]).
    pub fn with_deadline_k(mut self, k: f64) -> Self {
        self.deadline_k = k;
        self
    }

    /// Override the gossip identity (see [`PeerConfig::gossip_addr`]).
    pub fn with_gossip_addr(mut self, addr: impl Into<String>) -> Self {
        self.gossip_addr = Some(addr.into());
        self
    }

    /// The address this box is known by fleet-wide: the gossip override,
    /// or the dial address when none is set.
    pub fn gossip_identity(&self) -> &str {
        self.gossip_addr.as_deref().unwrap_or(&self.addr)
    }

    /// Dial this peer honoring the deadline budget: a bounded
    /// `connect_timeout` where the address parses as a socket address
    /// (hostnames fall back to the blocking dial), then arm the per-op
    /// read/write timeouts on the fresh socket.  This is the **only** dial
    /// path the fabric uses, so pooled connections can never again come up
    /// without their deadlines armed.
    pub fn dial(&self) -> Result<KvClient> {
        let conn = match self.deadline {
            Some(b) if self.addr.parse::<std::net::SocketAddr>().is_ok() => {
                KvClient::connect_timeout(&self.addr, b.connect)?
            }
            _ => KvClient::connect(&self.addr)?,
        };
        if let Some(b) = self.deadline {
            conn.set_io_timeout(Some(b.op))
                .with_context(|| format!("arm deadlines on {}", self.addr))?;
        }
        Ok(conn)
    }
}

/// One cache box as a client sees it: pooled connection, per-peer shaper,
/// per-peer catalog + sync loop, per-peer ledger.
pub struct Peer {
    pub cfg: PeerConfig,
    /// Resolved link model (the per-peer override or the client default).
    pub link: LinkModel,
    conn: Option<KvClient>,
    pub shaper: Shaper,
    /// This peer's local catalog: one Bloom filter + sync cursor per box,
    /// so a lookup can name the box(es) that claim a range.
    pub catalog: Arc<Mutex<LocalCatalog>>,
    /// This peer's sketch table — the semantic tier's per-box view, merged
    /// by the same sync loop that merges the catalog (empty forever against
    /// a legacy box, which degrades that peer to exact-only matching).
    pub sketches: Arc<Mutex<SketchTable>>,
    sync: Option<CatalogSync>,
    pub ledger: PeerLedger,
    /// Liveness reporting handle; `None` for standalone fabric use
    /// (benches, tests) where no membership view exists.
    health: Option<HealthSink>,
}

impl Peer {
    /// Dial the peer eagerly (construction fails fast when a configured box
    /// is unreachable, like the single-box client always has).
    pub fn connect(
        cfg: PeerConfig,
        link: LinkModel,
        seed: u64,
        min_hit_tokens: usize,
    ) -> Result<Peer> {
        let conn = cfg
            .dial()
            .with_context(|| format!("cache box at {}", cfg.addr))?;
        let mut catalog = LocalCatalog::new();
        catalog.min_hit_tokens = min_hit_tokens;
        Ok(Peer {
            link: link.clone(),
            conn: Some(conn),
            shaper: Shaper::new(link, seed),
            catalog: Arc::new(Mutex::new(catalog)),
            sketches: Arc::new(Mutex::new(SketchTable::new())),
            sync: None,
            ledger: PeerLedger { addr: cfg.addr.clone(), ..Default::default() },
            health: None,
            cfg,
        })
    }

    /// Attach the membership reporting handle for this peer.  Hot-path
    /// outcomes ([`Peer::note_io`]) flow through it from then on.
    pub fn set_health(&mut self, sink: HealthSink) {
        self.health = Some(sink);
    }

    /// Report one hot-path I/O outcome: the ledger counts deadline
    /// expiries, and the membership view (when attached) runs its state
    /// machine.  Safe to call with no sink — standalone fabrics just keep
    /// the ledger.
    pub fn note_io(&mut self, outcome: Outcome) {
        if outcome == Outcome::IoTimeout {
            self.ledger.timeouts += 1;
        }
        if outcome == Outcome::Overloaded {
            self.ledger.sheds += 1;
        }
        if let Some(h) = &self.health {
            h.report(outcome);
        }
    }

    /// Start this peer's background catalog-sync loop (own connection, so
    /// it never contends with the request-path connection).
    pub fn spawn_sync(&mut self, interval: Duration) -> Result<()> {
        self.spawn_sync_with(interval, None)
    }

    /// [`Peer::spawn_sync`] with a liveness sink: every sync round doubles
    /// as a heartbeat, and a dead peer's backoff probes double as recovery
    /// detection (the only path out of `Dead`).
    pub fn spawn_sync_with(
        &mut self,
        interval: Duration,
        health: Option<HealthSink>,
    ) -> Result<()> {
        self.spawn_sync_gossip(interval, health, None)
    }

    /// [`Peer::spawn_sync_with`] plus SWIM gossip piggybacked on the sync
    /// wire: each successful round swaps membership digests with this box
    /// (see [`CatalogSync::spawn_gossip`]).
    pub fn spawn_sync_gossip(
        &mut self,
        interval: Duration,
        health: Option<HealthSink>,
        gossip: Option<Arc<Membership>>,
    ) -> Result<()> {
        self.spawn_sync_semantic(interval, health, gossip, false)
    }

    /// [`Peer::spawn_sync_gossip`] plus the semantic tier: when `sketches`
    /// is set the loop also pulls this box's sketch sections into
    /// [`Peer::sketches`] (see [`CatalogSync::spawn_semantic`]).
    pub fn spawn_sync_semantic(
        &mut self,
        interval: Duration,
        health: Option<HealthSink>,
        gossip: Option<Arc<Membership>>,
        sketches: bool,
    ) -> Result<()> {
        if self.sync.is_none() {
            self.sync = Some(CatalogSync::spawn_semantic(
                self.cfg.addr.clone(),
                Arc::clone(&self.catalog),
                interval,
                health,
                gossip,
                sketches.then(|| Arc::clone(&self.sketches)),
            )?);
        }
        Ok(())
    }

    /// Re-arm this peer's op deadline for an operation expected to move
    /// `op_bytes`: `k ×` the link model's expected transfer time, floored
    /// by the configured static budget and doubled while the peer is
    /// `Suspect` (a suspected box gets one *wider* benefit of the doubt,
    /// not a hair-trigger).  No-op without a static budget, without `k`, or
    /// without a live pooled connection.
    pub fn arm_adaptive_deadline(&mut self, op_bytes: usize) {
        let Some(base) = self.cfg.deadline else { return };
        if self.cfg.deadline_k <= 0.0 {
            return;
        }
        let expected_s = self.link.rtt.as_secs_f64()
            + op_bytes as f64 / self.link.goodput_bps.max(1.0);
        let widen = self
            .health
            .as_ref()
            .is_some_and(|h| h.state() == PeerHealth::Suspect);
        let b = base.adaptive(expected_s, self.cfg.deadline_k, widen);
        if let Some(conn) = &self.conn {
            let _ = conn.set_io_timeout(Some(b.op));
        }
    }

    pub fn stop_sync(&mut self) {
        if let Some(s) = self.sync.take() {
            s.stop();
        }
    }

    /// Completed background sync rounds against this peer.
    pub fn sync_rounds(&self) -> u64 {
        self.sync
            .as_ref()
            .map_or(0, |s| s.rounds.load(Ordering::SeqCst))
    }

    /// The pooled request-path connection plus this peer's shaper, split as
    /// disjoint borrows so a caller can shape a transfer on the very
    /// connection it drives.  Redials once if the previous connection was
    /// torn down by an error — every operation (downloads, uploads, manual
    /// syncs) reuses this one socket instead of dialing per call.
    pub fn conn_parts(&mut self) -> Option<(&mut KvClient, &mut Shaper)> {
        if self.conn.is_none() {
            self.conn = self.cfg.dial().ok();
        }
        match &mut self.conn {
            Some(c) => Some((c, &mut self.shaper)),
            None => None,
        }
    }

    /// Tear the pooled connection down after an I/O error; the next
    /// [`Peer::conn_parts`] call redials.
    pub fn mark_dead_conn(&mut self) {
        self.conn = None;
    }

    /// Whether the pooled connection is currently up (a dead box shows up
    /// here after its first failed operation).
    pub fn is_connected(&self) -> bool {
        self.conn.is_some()
    }
}

/// Result of a successful fabric range fetch.
pub struct FabricFetch {
    pub state: KvState,
    /// Payload bytes moved over all participating links (head + chunks).
    pub wire: usize,
    /// Authoritative compression flag from the entry's own header.
    pub compressed: bool,
    /// The entry's full chunk index (future `SPLICE` base metadata).
    pub entries: Vec<ChunkEntry>,
    /// Caller id of the peer that served the head — the natural `SPLICE`
    /// base peer, since it certainly holds the full entry.
    pub head_peer: usize,
    /// Re-plan rounds the fetch needed after share failures.
    pub re_plans: u64,
    /// Shares (including head attempts) that failed along the way.
    pub share_failures: u64,
    /// Shares (including head attempts) a saturated peer shed with `BUSY`.
    /// Health-neutral and *not* counted into `share_failures`: the box is
    /// alive, its admission queue is just full.
    pub busy_shares: u64,
    /// Free re-plan rounds granted because a peer answered `BUSY` (capped
    /// at one per fetch so a perpetually-saturated peer cannot spin the
    /// re-plan loop).
    pub busy_replans: u64,
    /// Whether more than one peer actually served chunks.
    pub multi_source: bool,
    /// Chunks whose rows came off a peer stripe.
    pub chunks_fetched: usize,
    /// Chunks whose rows the local feeder recomputed ([`LocalRecompute`]).
    pub chunks_recomputed: usize,
}

/// The local-recompute feeder: the second chunk source next to the
/// per-peer reply streams.  The client builds one when chunk planning is
/// on (`--plan chunk`) and the device models recompute; the fabric stays
/// engine-free — it only sees raw row payloads.
pub struct LocalRecompute<'a> {
    /// Produce raw row payloads for the requested chunk ids — exactly
    /// `stored_rows(c) * stride` bytes each, the
    /// [`StateAssembler::commit_chunk`] contract.  Causality means the
    /// feeder prefills up to the highest requested chunk even if only some
    /// ids are wanted (the planner only requests prefixes on the happy
    /// path; rescue prices that cost explicitly).  A `seed` — the
    /// assembler's already-committed contiguous row prefix
    /// ([`StateAssembler::seed_state`]) — lets the feeder resume prefill
    /// from `seed.n_tokens` instead of token 0, so a mid-restore rescue
    /// costs the orphan span, not its end offset.  `None` (or missing ids)
    /// leaves those chunks unfed — the re-plan loop treats them like any
    /// other orphan.
    pub feed:
        &'a mut dyn FnMut(&[usize], Option<KvState>) -> Option<Vec<(usize, Vec<u8>)>>,
    /// Modelled device prefill rate (ms/token) the cost model prices
    /// recompute with; `<= 0` disables planning (host profile).
    pub prefill_ms_per_tok: f64,
}

/// Validate a fetched head and build the streaming assembler from it: the
/// head must be exactly the promised length, parse + verify
/// ([`StateAssembler::new`]: identity, index crc) and declare the chunk
/// size the alias promised — anything else is a stale or short entry and
/// the caller falls back.  Shared by every head-acquisition path so a
/// future validation fix cannot land in one and miss the others.
pub fn checked_assembler(
    head: &[u8],
    head_len: usize,
    ct: usize,
    m: usize,
    hash: &str,
    dims: (usize, usize, usize, usize),
) -> Option<StateAssembler> {
    if head.len() != head_len {
        return None; // entry shorter than the alias promised
    }
    let asm = match StateAssembler::new(head, m, hash, dims) {
        Ok(a) => a,
        Err(e) => {
            log_debug!("fabric", "range head rejected: {e}");
            return None;
        }
    };
    if asm.chunk_tokens() != ct {
        return None; // stale geometry: re-written with another chunk size
    }
    Some(asm)
}

/// Pull the outstanding chunk replies off a streamed batch, shaping each
/// arrival and feeding it straight into the assembler — the
/// wire-overlapped decode loop for a single in-order source.  `false` on
/// any missing/short/invalid reply (the caller drains the stream and falls
/// back).
pub fn consume_chunk_stream(
    replies: &mut StreamingReplies<'_>,
    sess: &mut StreamSession<'_>,
    asm: &mut StateAssembler,
) -> bool {
    for c in asm.fed_chunks()..asm.expected_chunks() {
        let bytes = match replies.next_reply() {
            Ok(Some(Value::Bulk(b))) => b,
            _ => return false, // evicted mid-stream / error reply / dead conn
        };
        // scripted byte-granular fault: damage this reply exactly as a
        // flaky link would, before timing or verification see it
        let bytes: SharedBytes = match sess.take_byte_fault(bytes.len()) {
            Some(f) => {
                let mut v = bytes.to_vec();
                if apply_byte_fault(f, &mut v).is_err() {
                    return false; // injected mid-stream reset
                }
                v.into()
            }
            None => bytes,
        };
        sess.arrived(bytes.len());
        if let Err(e) = asm.feed_chunk(&bytes) {
            log_debug!("fabric", "streamed chunk {c} rejected: {e}");
            return false;
        }
    }
    true
}

/// Outcome of one head-acquisition attempt against one peer.
enum HeadOutcome {
    /// Single-claimer fast path: the `GETCHUNKS` stream already carried
    /// every matched chunk — assembly is complete.
    Done { asm: StateAssembler, wire: usize },
    /// Multi-claimer path: head verified, chunks still to fetch.
    Head { asm: StateAssembler, wire: usize },
    /// The key is authoritatively absent on this peer (evicted / FP).
    Absent,
    /// The entry is unusable via the range path (stale geometry, short or
    /// corrupt head) — fall back to a full-blob download.
    Reject,
    /// Connection-level failure: mark the peer dead and try the next one.
    /// Carries the liveness classification — a deadline expiry is
    /// `IoTimeout` (→ `Suspect`), a closed/reset socket `IoDead`.
    PeerDown(Outcome),
    /// The peer shed the request at its admission gate (`BUSY` reply): it
    /// is alive but saturated.  Health-neutral — rotate to the next
    /// claimer without tearing the connection down or burning a strike.
    Busy,
    /// The peer does not speak `GETCHUNKS` (or the entry is not chunked):
    /// retry via the byte-oriented GETRANGE compatibility path.
    Unsupported,
}

/// Head acquisition over server-push `GETCHUNKS`: one request returns the
/// head — and, with a single claimer, every matched chunk behind it in the
/// same streamed reply, which removes the deflated path's old extra head
/// round trip entirely.
#[allow(clippy::too_many_arguments)]
fn acquire_head_push(
    peer: &mut Peer,
    target: &[u8],
    head_len: usize,
    ct: usize,
    m: usize,
    k: usize,
    hash: &str,
    dims: (usize, usize, usize, usize),
    single: bool,
) -> HeadOutcome {
    let Some((conn, shaper)) = peer.conn_parts() else {
        return HeadOutcome::PeerDown(Outcome::IoDead);
    };
    let want_rows = if single { m } else { 0 };
    let mut stream = match conn.getchunks_stream(target, want_rows) {
        Ok(ChunksReply::Stream(s)) => s,
        Ok(ChunksReply::Terminal(Value::Nil)) => return HeadOutcome::Absent,
        // BUSY must be discriminated *before* the generic error arm: a shed
        // is not a protocol gap, and retrying it over GETRANGE would only
        // hit the same full admission queue with a second request.
        Ok(ChunksReply::Terminal(Value::Error(e))) if e.starts_with("BUSY") => {
            return HeadOutcome::Busy;
        }
        Ok(ChunksReply::Terminal(Value::Error(_))) => return HeadOutcome::Unsupported,
        Ok(ChunksReply::Terminal(_)) => return HeadOutcome::Reject,
        Err(e) => {
            log_debug!("fabric", "GETCHUNKS failed: {e}");
            return HeadOutcome::PeerDown(classify_io_err(&e));
        }
    };
    let expected = if single { 1 + k } else { 1 };
    if stream.remaining() != expected {
        // stale geometry: the entry was re-written with another chunk size
        let _ = stream.drain();
        return HeadOutcome::Reject;
    }
    let mut sess = shaper.shaped_stream();
    let head = match stream.next_reply() {
        Ok(Some(Value::Bulk(b))) => b,
        Ok(_) => {
            let _ = stream.drain();
            return HeadOutcome::Reject;
        }
        Err(e) => return HeadOutcome::PeerDown(classify_io_err(&e)),
    };
    sess.arrived(head.len());
    let Some(mut asm) = checked_assembler(&head, head_len, ct, m, hash, dims) else {
        let _ = stream.drain();
        return HeadOutcome::Reject;
    };
    if !single {
        let wire = sess.bytes();
        sess.finish();
        return HeadOutcome::Head { asm, wire };
    }
    if !consume_chunk_stream(&mut stream, &mut sess, &mut asm) {
        let _ = stream.drain();
        return HeadOutcome::Reject;
    }
    let wire = sess.bytes();
    sess.finish();
    HeadOutcome::Done { asm, wire }
}

/// Head acquisition over plain byte ranges — the compatibility path for
/// boxes (or entries) that cannot serve `GETCHUNKS`.  With a single
/// claimer this is exactly the pre-push pipeline: raw bodies ride one
/// pipelined round trip (chunk spans are layout arithmetic), deflated
/// bodies pay the head round trip first.
#[allow(clippy::too_many_arguments)]
fn acquire_head_getrange(
    peer: &mut Peer,
    target: &[u8],
    total_rows: usize,
    head_len: usize,
    ct: usize,
    m: usize,
    k: usize,
    hash: &str,
    dims: (usize, usize, usize, usize),
    compressed: bool,
    single: bool,
) -> HeadOutcome {
    let (l, _, kh, d) = dims;
    let lo = BlobLayout::new(hash, l, kh, d).with_chunk_tokens(ct);
    let stride = lo.token_stride();
    let Some((conn, shaper)) = peer.conn_parts() else {
        return HeadOutcome::PeerDown(Outcome::IoDead);
    };

    if single && !compressed {
        // raw chunk spans are pure layout arithmetic: head + one GETRANGE
        // per chunk in one pipelined write, consumed as a stream
        let mut reqs = Vec::with_capacity(k + 1);
        reqs.push(getrange_req(target, 0, head_len));
        let mut off = head_len;
        for c in 0..k {
            let span = lo.chunk_rows(c, total_rows) * stride;
            reqs.push(getrange_req(target, off, span));
            off += span;
        }
        let mut replies = match conn.send_reqs(&reqs) {
            Ok(r) => r,
            Err(e) => {
                log_debug!("fabric", "range batch failed: {e}");
                return HeadOutcome::PeerDown(classify_io_err(&e));
            }
        };
        let mut sess = shaper.shaped_stream();
        let head = match replies.next_reply() {
            Ok(Some(Value::Bulk(b))) => b,
            Ok(_) => {
                let _ = replies.drain();
                return HeadOutcome::Reject; // evicted between alias GET and now
            }
            Err(e) => return HeadOutcome::PeerDown(classify_io_err(&e)),
        };
        sess.arrived(head.len());
        let Some(mut asm) = checked_assembler(&head, head_len, ct, m, hash, dims) else {
            let _ = replies.drain();
            return HeadOutcome::Reject;
        };
        if !consume_chunk_stream(&mut replies, &mut sess, &mut asm) {
            let _ = replies.drain();
            return HeadOutcome::Reject;
        }
        let wire = sess.bytes();
        sess.finish();
        return HeadOutcome::Done { asm, wire };
    }

    // deflated chunk lengths are data-dependent (and a multi-source head is
    // always fetched alone): head first
    let head = match shaper.shaped_post(|| {
        let r = conn.getrange(target, 0, head_len);
        let n = r
            .as_ref()
            .map(|o| o.as_ref().map_or(0, |b| b.len()))
            .unwrap_or(0);
        (r, n)
    }) {
        Ok(Some(b)) => b,
        Ok(None) => return HeadOutcome::Absent,
        Err(e) => {
            log_debug!("fabric", "head fetch failed: {e}");
            return HeadOutcome::PeerDown(classify_io_err(&e));
        }
    };
    let Some(mut asm) = checked_assembler(&head, head_len, ct, m, hash, dims) else {
        return HeadOutcome::Reject;
    };
    if !single {
        return HeadOutcome::Head { asm, wire: head.len() };
    }
    let mut reqs = Vec::with_capacity(k);
    let mut off = head_len;
    for c in 0..k {
        let clen = asm.chunk_len(c);
        if clen == 0 {
            return HeadOutcome::Reject; // a zero-length stored chunk is never written
        }
        reqs.push(getrange_req(target, off, clen));
        off += clen;
    }
    let mut replies = match conn.send_reqs(&reqs) {
        Ok(r) => r,
        Err(e) => {
            log_debug!("fabric", "range batch failed: {e}");
            return HeadOutcome::PeerDown(classify_io_err(&e));
        }
    };
    let mut sess = shaper.shaped_stream();
    if !consume_chunk_stream(&mut replies, &mut sess, &mut asm) {
        let _ = replies.drain();
        return HeadOutcome::Reject;
    }
    let wire = head.len() + sess.bytes();
    sess.finish();
    HeadOutcome::Done { asm, wire }
}

/// Queue-depth-aware cost of a peer's link: the static link model derated
/// by the peer's smoothed observed/expected service-time ratio
/// ([`PeerLedger::service_slowdown`]).  A box whose shares keep running
/// slow — queue building behind its admission gate — sheds planner share
/// to the survivors *before* it starts shedding requests.
fn peer_link_cost(peer: &Peer) -> LinkCost {
    LinkCost::from_link(&peer.link).derated(peer.ledger.service_slowdown())
}

/// Outcome of one worker's chunk share.
struct ShareOutcome {
    wire: usize,
    /// Chunks this share actually fed into the assembler.
    fed: usize,
    ok: bool,
    /// The peer answered `Nil` — it authoritatively does not hold the
    /// entry (evicted copy, Bloom FP, or a ring peer holding only the
    /// range alias).  Distinguished from genuine failures so discovering
    /// an absent claimer never burns the bounded re-plan budget.
    absent: bool,
    /// The peer shed this share at its admission gate (`BUSY` reply): it
    /// is alive but saturated.  Health-neutral — the share goes back into
    /// the re-plan pool with one free round, not a health strike.
    busy: bool,
}

/// I/O half of one share: pipelined GETRANGE batch for this peer's chunk
/// ids, each reply shaped, crc-verified and inflated *outside* the shared
/// lock ([`ChunkVerifier`] — concurrent peers must not serialize their
/// decode behind one mutex), then committed into the assembler under it (a
/// bounded scatter).  Returns the outcome plus the liveness classification
/// when the connection died — `Some(IoTimeout)` for a deadline expiry,
/// `Some(IoDead)` for a closed socket; either way the caller tears the
/// connection down (a timed-out reply stream is desynced and unusable
/// even though the box may still be alive).  The borrow rules keep
/// `mark_dead_conn` out of reach while the reply stream lives.
fn fetch_share_io(
    peer: &mut Peer,
    target: &[u8],
    chunks: &[usize],
    geom: &[(usize, usize)],
    verifier: &ChunkVerifier,
    asm: &Mutex<Option<StateAssembler>>,
) -> (ShareOutcome, Option<Outcome>) {
    let fail = ShareOutcome { wire: 0, fed: 0, ok: false, absent: false, busy: false };
    let Some((conn, shaper)) = peer.conn_parts() else {
        return (fail, Some(Outcome::IoDead));
    };
    let reqs: Vec<Value> = chunks
        .iter()
        .map(|&c| getrange_req(target, geom[c].0, geom[c].1))
        .collect();
    let mut replies = match conn.send_reqs(&reqs) {
        Ok(r) => r,
        Err(e) => {
            log_debug!("fabric", "share batch failed: {e}");
            return (fail, Some(classify_io_err(&e)));
        }
    };
    let mut sess = shaper.shaped_stream();
    let mut fed = 0usize;
    let mut ok = true;
    let mut dead: Option<Outcome> = None;
    let mut absent = false;
    let mut busy = false;
    for &c in chunks {
        let bytes = match replies.next_reply() {
            Ok(Some(Value::Bulk(b))) => b,
            Ok(Some(Value::Nil)) => {
                ok = false; // the key is not on this peer at all
                absent = true;
                break;
            }
            Ok(Some(Value::Error(e))) if e.starts_with("BUSY") => {
                ok = false; // shed at the admission gate, not a failure
                busy = true;
                break;
            }
            Ok(_) => {
                ok = false; // error reply mid-share
                break;
            }
            Err(e) => {
                ok = false;
                dead = Some(classify_io_err(&e));
                break;
            }
        };
        // scripted byte-granular fault: truncate/corrupt this reply (the
        // crc check below rejects it chunk-granularly) or cut the stream
        // mid-reply (an injected reset tears the pooled connection down
        // like a real one would)
        let bytes: SharedBytes = match sess.take_byte_fault(bytes.len()) {
            Some(f) => {
                let mut v = bytes.to_vec();
                match apply_byte_fault(f, &mut v) {
                    Ok(()) => v.into(),
                    Err(_) => {
                        ok = false;
                        dead = Some(Outcome::IoDead);
                        break;
                    }
                }
            }
            None => bytes,
        };
        sess.arrived(bytes.len());
        // CPU-heavy half outside the lock: crc + bounded inflate
        let payload = match verifier.verify(c, &bytes) {
            Ok(p) => p,
            Err(e) => {
                log_debug!("fabric", "share chunk {c} rejected: {e}");
                ok = false;
                break;
            }
        };
        // cheap half under the lock: once-only bookkeeping + scatter
        let committed = match asm.lock() {
            Ok(mut guard) => match guard.as_mut() {
                Some(a) => match a.commit_chunk(c, &payload) {
                    Ok(()) => true,
                    Err(e) => {
                        log_debug!("fabric", "share chunk {c} not committed: {e}");
                        false
                    }
                },
                None => false,
            },
            Err(_) => false,
        };
        if !committed {
            ok = false;
            break;
        }
        fed += 1;
    }
    let wire = sess.bytes();
    sess.finish();
    if !ok && dead.is_none() {
        // keep the connection frame-synced for the re-plan / fallback
        // (a shed burst is one BUSY error per pipelined request — draining
        // them leaves the very same connection usable next round)
        let _ = replies.drain();
    }
    (ShareOutcome { wire, fed, ok, absent, busy }, dead)
}

/// One worker share: run the I/O, then settle the peer's ledger,
/// connection state and liveness view.
fn fetch_share(
    peer: &mut Peer,
    target: &[u8],
    chunks: Vec<usize>,
    geom: &[(usize, usize)],
    verifier: &ChunkVerifier,
    asm: &Mutex<Option<StateAssembler>>,
) -> ShareOutcome {
    let t0 = Instant::now();
    // deadline scaled to what this share actually moves over this link
    let expected: usize = chunks.iter().map(|&c| geom[c].1).sum();
    peer.arm_adaptive_deadline(expected);
    let (outcome, dead) = fetch_share_io(peer, target, &chunks, geom, verifier, asm);
    if let Some(o) = dead {
        // even on a mere timeout the pooled connection must go: its reply
        // stream is desynced — only the membership verdict differs
        peer.mark_dead_conn();
        peer.note_io(o);
    } else if outcome.busy {
        // alive-but-saturated: the drained connection stays pooled and the
        // membership view records a health-neutral Overloaded observation
        peer.note_io(Outcome::Overloaded);
    } else if outcome.ok {
        peer.note_io(Outcome::IoOk);
    }
    if outcome.ok {
        peer.ledger.fetch_shares += 1;
        // queue-depth signal for the planner: how long this share took
        // against what the link model alone predicts.  Only successful
        // shares feed the EWMA — failures and sheds have their own
        // (health / free-replan) channels.
        let expected_ms = (peer.link.rtt.as_secs_f64()
            + expected as f64 / peer.link.goodput_bps.max(1.0))
            * 1e3;
        peer.ledger
            .note_service_time(t0.elapsed().as_secs_f64() * 1e3, expected_ms);
    } else if !outcome.busy {
        peer.ledger.share_failures += 1;
    }
    peer.ledger.chunks_served += outcome.fed as u64;
    peer.ledger.bytes_down += outcome.wire as u64;
    peer.ledger.breakdown.add(Phase::Redis, t0.elapsed());
    outcome
}

/// Drive the local-recompute feeder for `chunks` and commit the returned
/// raw row payloads into the shared assembler.  Returns how many chunks
/// were actually committed; anything missing stays unfed and the re-plan
/// loop handles it like any other orphan.
fn feed_local(
    local: &mut LocalRecompute<'_>,
    chunks: &[usize],
    seed: Option<KvState>,
    asm: &Mutex<Option<StateAssembler>>,
) -> usize {
    if chunks.is_empty() {
        return 0;
    }
    let Some(payloads) = (local.feed)(chunks, seed) else {
        log_debug!("fabric", "local feeder declined {} chunks", chunks.len());
        return 0;
    };
    let mut fed = 0usize;
    for (c, payload) in payloads {
        let committed = match asm.lock() {
            Ok(mut guard) => match guard.as_mut() {
                Some(a) => match a.commit_chunk(c, &payload) {
                    Ok(()) => true,
                    Err(e) => {
                        log_debug!("fabric", "recomputed chunk {c} not committed: {e}");
                        false
                    }
                },
                None => false,
            },
            Err(_) => false,
        };
        if committed {
            fed += 1;
        }
    }
    fed
}

/// Run one round of chunk shares concurrently — one scoped thread per
/// participating peer, each driving its own pipelined reply stream into
/// the shared assembler — plus, when a mixed plan assigned it work, the
/// local-recompute feeder on the calling thread (paced device compute
/// elapses here while each share thread sleeps on its own modelled wire,
/// so the two feeders genuinely overlap).  Returns (wire bytes moved,
/// failed shares, slots that fed at least one chunk, failed slots, slots
/// that answered "no such key", slots shed with `BUSY`, chunks the feeder
/// recomputed).
#[allow(clippy::type_complexity)]
fn run_shares(
    claimers: &mut [(usize, &mut Peer)],
    assign: &[(usize, Vec<usize>)],
    local: Option<(&mut LocalRecompute<'_>, &[usize])>,
    target: &[u8],
    geom: &[(usize, usize)],
    verifier: &ChunkVerifier,
    asm: &Mutex<Option<StateAssembler>>,
) -> (usize, u64, Vec<usize>, Vec<usize>, Vec<usize>, Vec<usize>, usize) {
    let mut slots: Vec<Option<&mut Peer>> =
        claimers.iter_mut().map(|(_, p)| Some(&mut **p)).collect();
    let mut wire = 0usize;
    let mut fails = 0u64;
    let mut contributed = Vec::new();
    let mut failed_slots = Vec::new();
    let mut absent_slots = Vec::new();
    let mut busy_slots = Vec::new();
    let mut recomputed = 0usize;
    std::thread::scope(|s| {
        let mut handles = Vec::new();
        for (slot, chunks) in assign {
            if chunks.is_empty() {
                continue;
            }
            let Some(peer) = slots[*slot].take() else {
                continue; // a slot assigned twice in one round is a plan bug
            };
            let chunks = chunks.clone();
            handles.push((
                *slot,
                s.spawn(move || fetch_share(peer, target, chunks, geom, verifier, asm)),
            ));
        }
        if let Some((lr, chunks)) = local {
            // round-0 local chunks are the leading prefix — nothing is
            // committed below them, so there is no seed to resume from
            recomputed = feed_local(lr, chunks, None, asm);
        }
        for (slot, h) in handles {
            match h.join() {
                Ok(o) => {
                    wire += o.wire;
                    if o.fed > 0 {
                        contributed.push(slot);
                    }
                    if o.absent {
                        absent_slots.push(slot);
                    }
                    if o.busy {
                        // a shed is neither a failure nor an absence: the
                        // slot stays plannable (the queue may have drained
                        // by the next round)
                        busy_slots.push(slot);
                    } else if !o.ok {
                        fails += 1;
                        failed_slots.push(slot);
                    }
                }
                Err(_) => {
                    fails += 1;
                    failed_slots.push(slot);
                }
            }
        }
    });
    (wire, fails, contributed, failed_slots, absent_slots, busy_slots, recomputed)
}

#[allow(clippy::too_many_arguments)]
fn finish_fetch(
    asm: StateAssembler,
    wire: usize,
    head_peer: usize,
    multi_source: bool,
    re_plans: u64,
    share_failures: u64,
    busy_shares: u64,
    busy_replans: u64,
    chunks_fetched: usize,
    chunks_recomputed: usize,
) -> Option<FabricFetch> {
    let compressed = asm.compressed();
    let entries = asm.entries().to_vec();
    match asm.finish() {
        Ok(state) => Some(FabricFetch {
            state,
            wire,
            compressed,
            entries,
            head_peer,
            re_plans,
            share_failures,
            busy_shares,
            busy_replans,
            multi_source,
            chunks_fetched,
            chunks_recomputed,
        }),
        Err(e) => {
            log_debug!("fabric", "assembly rejected: {e}");
            None
        }
    }
}

/// The fabric range download (module docs): fetch the first `m` rows of
/// the ECS3 entry stored under `target` from the claiming peers, splitting
/// whole chunks across them and re-planning around failures.  `claimers`
/// pairs each peer with its caller-side id (reported back in
/// [`FabricFetch::head_peer`]); a single claimer is simply the degenerate
/// one-stripe plan.  A `local` feeder turns the stripe split into a mixed
/// per-chunk fetch/recompute plan (module docs).  `None` means the range
/// path could not complete — the caller falls back to
/// [`fetch_full_entry`], never to a questionable restore.
#[allow(clippy::too_many_arguments)]
pub fn fetch_prefix_multi(
    claimers: &mut [(usize, &mut Peer)],
    planner: &PeerPlanner,
    target: &[u8],
    total_rows: usize,
    compressed: bool,
    ct: usize,
    m: usize,
    hash: &str,
    dims: (usize, usize, usize, usize),
    local: Option<LocalRecompute<'_>>,
) -> Option<FabricFetch> {
    let n = claimers.len();
    if n == 0 {
        return None;
    }
    let (l, _, kh, d) = dims;
    let lo = BlobLayout::new(hash, l, kh, d).with_chunk_tokens(ct);
    let head_len = lo.payload_off(total_rows);
    let k = lo.prefix_chunks(m);
    // a feeder with a modelled prefill rate arms per-chunk planning; the
    // host profile (rate 0) keeps the historical all-fetch behaviour
    let mut local = local.filter(|lr| lr.prefill_ms_per_tok > 0.0 && k > 0);
    // one *live* claimer is a single-source fetch: the GETCHUNKS request
    // carries every chunk in one round trip (dead-marked claimers don't
    // force the split head+stripes shape — after a peer death the
    // survivor keeps serving hits at full single-source speed; the head
    // rotation below still redials them, so a recovered box re-joins).
    // Chunk planning needs the head+stripes shape even with one claimer:
    // the plan prices the exact stored chunk lengths from the index, and
    // per-chunk shares are what let one bad chunk degrade to one chunk of
    // recompute instead of a whole-range fallback.
    let live = claimers.iter().filter(|(_, p)| p.is_connected()).count();
    let single = live <= 1 && local.is_none();
    let mut share_failures = 0u64;
    // shares (head attempts included) a saturated peer shed with BUSY, and
    // the free re-plan rounds those sheds earned (at most one per fetch)
    let mut busy_shares = 0u64;
    let mut busy_replans = 0u64;
    let mut busy_free_granted = false;
    // slots that authoritatively answered "no such key" during head
    // rotation (evicted copy, Bloom FP, or a ring peer holding only the
    // range alias, not the target blob): they cannot serve any share, so
    // planning stripes onto them would only burn re-plan rounds
    let mut absent_slots: Vec<usize> = Vec::new();

    // -- head acquisition: rotate across claimers until one answers -------
    let mut acquired: Option<(usize, StateAssembler, usize)> = None;
    for slot in 0..n {
        let t0 = Instant::now();
        claimers[slot].1.arm_adaptive_deadline(head_len);
        let mut out = acquire_head_push(
            &mut *claimers[slot].1,
            target,
            head_len,
            ct,
            m,
            k,
            hash,
            dims,
            single,
        );
        if matches!(out, HeadOutcome::Unsupported) {
            out = acquire_head_getrange(
                &mut *claimers[slot].1,
                target,
                total_rows,
                head_len,
                ct,
                m,
                k,
                hash,
                dims,
                compressed,
                single,
            );
        }
        let peer = &mut *claimers[slot].1;
        peer.ledger.breakdown.add(Phase::Redis, t0.elapsed());
        match out {
            HeadOutcome::Done { asm, wire } => {
                peer.ledger.fetch_shares += 1;
                peer.ledger.chunks_served += k as u64;
                peer.ledger.bytes_down += wire as u64;
                peer.note_io(Outcome::IoOk);
                let head_peer = claimers[slot].0;
                return finish_fetch(
                    asm,
                    wire,
                    head_peer,
                    false,
                    0,
                    share_failures,
                    busy_shares,
                    busy_replans,
                    k,
                    0,
                );
            }
            HeadOutcome::Head { asm, wire } => {
                peer.ledger.bytes_down += wire as u64;
                peer.note_io(Outcome::IoOk);
                acquired = Some((slot, asm, wire));
                break;
            }
            HeadOutcome::Absent => {
                // evicted on this claimer (or a Bloom FP / alias-only ring
                // peer); a replicated copy on another claimer can still
                // serve the range path — but this slot gets no stripe
                absent_slots.push(slot);
                log_debug!(
                    "fabric",
                    "head peer {} lost the entry; rotating",
                    peer.cfg.addr
                );
            }
            HeadOutcome::Reject => return None, // caller: full-blob fallback
            HeadOutcome::Busy => {
                // shed at the admission gate: the reply was a single
                // frame-synced BUSY error, so the pooled connection stays
                // up and the peer keeps its health — just rotate
                peer.note_io(Outcome::Overloaded);
                busy_shares += 1;
                log_debug!(
                    "fabric",
                    "head peer {} busy; rotating",
                    peer.cfg.addr
                );
            }
            HeadOutcome::PeerDown(Outcome::Overloaded) => {
                // BUSY surfaced through a non-pipelined error path
                // (`classify_io_err` walked the error chain): the reply
                // was consumed whole, so the connection is still synced —
                // same health-neutral rotation as `HeadOutcome::Busy`
                peer.note_io(Outcome::Overloaded);
                busy_shares += 1;
                log_debug!(
                    "fabric",
                    "head peer {} busy; rotating",
                    peer.cfg.addr
                );
            }
            HeadOutcome::PeerDown(o) => {
                peer.mark_dead_conn();
                peer.note_io(o);
                peer.ledger.share_failures += 1;
                share_failures += 1;
                log_debug!(
                    "fabric",
                    "head peer {} down; rotating",
                    peer.cfg.addr
                );
            }
            HeadOutcome::Unsupported => {
                // only reachable if the GETRANGE retry path itself is
                // skipped; treat like the historical dead-conn rotation
                // without a liveness verdict (it is a protocol gap, not a
                // peer death)
                peer.mark_dead_conn();
                peer.ledger.share_failures += 1;
                share_failures += 1;
                log_debug!(
                    "fabric",
                    "head peer {} unsupported; rotating",
                    peer.cfg.addr
                );
            }
        }
    }
    let (head_slot, asm, head_wire) = acquired?;

    // chunk geometry from the verified index: (byte offset, stored length)
    // per chunk — identical on every peer that holds the entry, and any
    // divergent replica is caught by the per-chunk crc check
    let mut geom = Vec::with_capacity(k);
    let mut off = head_len;
    for e in asm.entries().iter().take(k) {
        let len = e.len as usize;
        if len == 0 {
            return None; // a zero-length stored chunk is never written
        }
        geom.push((off, len));
        off += len;
    }

    // lock-free verification geometry for the worker threads (one index
    // snapshot per fetch, not per chunk)
    let verifier = asm.verifier();
    let asm_cell = Mutex::new(Some(asm));
    let mut wire_total = head_wire;
    let mut re_plans = 0u64;
    // slots that actually fed chunks — `multi_source` reports what
    // happened, not what round 0 planned
    let mut sources: Vec<usize> = Vec::new();
    // slots that failed a share this fetch: a copy that came back Nil,
    // short or corrupt will do so again — re-planning onto it only burns
    // the bounded rounds, so survivors exclude them even while connected
    let mut bad_slots: Vec<usize> = Vec::new();

    // round 0: goodput-weighted contiguous stripes, head peer first.
    // Claimers already known dead (alias-GET or head-rotation casualties)
    // or known *absent* (rotation proved they lost the entry) get no
    // stripe — a share planned onto them is a guaranteed failure that
    // would burn one of the bounded re-plan rounds for nothing.
    let mut order: Vec<usize> = Vec::with_capacity(n);
    order.push(head_slot);
    order.extend((0..n).filter(|&s| {
        s != head_slot && !absent_slots.contains(&s) && claimers[s].1.is_connected()
    }));
    // queue-depth-aware stripe weights: effective (derated) goodput, not
    // the static link model — a peer running hot takes a smaller stripe
    let weights: Vec<f64> = order
        .iter()
        .map(|&s| peer_link_cost(&*claimers[s].1).goodput_bps)
        .collect();

    // mixed plan (feeder attached): price each chunk's exact stored wire
    // bytes against the device's prefill rate over the participants'
    // links.  Causal attention makes executable plans prefix-shaped —
    // recompute chunks [0, split) locally, stripe [split, k) over peers.
    let split = match &local {
        Some(lr) => {
            let chunk_costs: Vec<ChunkCost> = (0..k)
                .map(|c| ChunkCost {
                    wire_bytes: geom[c].1,
                    tokens: ct.min(m - c * ct),
                })
                .collect();
            let links: Vec<LinkCost> = order
                .iter()
                .map(|&s| peer_link_cost(&*claimers[s].1))
                .collect();
            plan_split(&chunk_costs, &links, lr.prefill_ms_per_tok).split_point()
        }
        None => 0,
    };
    let mut chunks_recomputed = 0usize;
    let mut local_round: Vec<usize> = (0..split).collect();
    if split > 0 {
        log_debug!(
            "fabric",
            "mixed plan: recompute chunks [0, {split}), fetch [{split}, {k})"
        );
    }

    let stripes = planner.split_chunks(k - split, &weights);
    let mut assign: Vec<(usize, Vec<usize>)> = order
        .iter()
        .zip(stripes)
        .map(|(&s, r)| (s, r.map(|c| c + split).collect()))
        .collect();

    let mut rounds = 0usize;
    // extra rounds granted when a share merely discovered an *absent*
    // claimer (Nil replies): each discovery permanently excludes that
    // slot, so the loop stays bounded (≤ n free rounds) and genuine
    // failures keep their own budget — an alias-only ring claimer can
    // never starve the re-plan of a real peer death
    let mut free_rounds = 0usize;
    // the local feeder gets one rescue shot per fetch: a successful rescue
    // feeds everything it was asked for, and a broken feeder must not be
    // able to spin the loop
    let mut rescue_spent = false;
    let read_unfed = || match asm_cell.lock() {
        Ok(guard) => guard.as_ref().map(|a| a.unfed_chunks()),
        Err(_) => None, // a worker panicked: never restore this
    };
    // the contiguous already-committed row prefix: what an incremental
    // rescue resumes prefill from instead of token 0
    let read_seed = || match asm_cell.lock() {
        Ok(guard) => guard.as_ref().and_then(|a| a.seed_state()),
        Err(_) => None,
    };
    loop {
        let local_arg = if local_round.is_empty() {
            None
        } else {
            local.as_mut().map(|lr| (lr, local_round.as_slice()))
        };
        let (wire, fails, contributed, failed_slots, absent_now, busy_now, fed_local) =
            run_shares(claimers, &assign, local_arg, target, &geom, &verifier, &asm_cell);
        chunks_recomputed += fed_local;
        local_round = Vec::new();
        wire_total += wire;
        share_failures += fails;
        for s in contributed {
            if !sources.contains(&s) {
                sources.push(s);
            }
        }
        if !absent_now.is_empty() {
            free_rounds += 1;
        }
        if !busy_now.is_empty() {
            busy_shares += busy_now.len() as u64;
            // a shed earns ONE free re-plan per fetch — like discovering an
            // absent claimer it is not the client's fault, but unlike
            // absence it is not a permanent exclusion, so an uncapped
            // grant would let a perpetually-saturated peer spin the loop.
            // Busy slots stay out of `bad_slots`: the queue may well have
            // drained by the next round.
            if !busy_free_granted {
                busy_free_granted = true;
                free_rounds += 1;
                busy_replans += 1;
            }
        }
        for s in failed_slots {
            if !bad_slots.contains(&s) {
                bad_slots.push(s);
            }
        }
        let mut unfed = read_unfed()?;
        if unfed.is_empty() {
            break;
        }
        let live: Vec<usize> = (0..n)
            .filter(|&s| {
                claimers[s].1.is_connected()
                    && !bad_slots.contains(&s)
                    && !absent_slots.contains(&s)
            })
            .collect();
        let budget_spent = rounds >= planner.max_replan_rounds + free_rounds;
        // orphan placement goes to *either* a survivor or the local feeder:
        // rescue when no survivor can serve (or the budget is spent), or
        // when the model prices prefill up to the highest orphan —
        // *resumed from the already-committed contiguous prefix*, so a
        // mid-restore rescue is priced (and paid) proportional to the
        // orphan span — below re-fetching over the surviving links
        let rescue = match &local {
            Some(lr) if !rescue_spent => {
                live.is_empty() || budget_spent || {
                    let refetch: Vec<ChunkCost> = unfed
                        .iter()
                        .map(|&c| ChunkCost { wire_bytes: geom[c].1, tokens: 0 })
                        .collect();
                    let links: Vec<LinkCost> = live
                        .iter()
                        .map(|&s| peer_link_cost(&*claimers[s].1))
                        .collect();
                    let all_fetch = vec![ChunkSource::Fetch; refetch.len()];
                    let fetch_s =
                        cost_of(&refetch, &links, lr.prefill_ms_per_tok, &all_fetch).total_s;
                    let hi = *unfed.iter().max().expect("unfed non-empty");
                    let seeded = match asm_cell.lock() {
                        Ok(g) => g.as_ref().map_or(0, |a| a.seeded_rows()),
                        Err(_) => 0,
                    };
                    let recompute_s = m.min((hi + 1) * ct).saturating_sub(seeded)
                        as f64
                        * lr.prefill_ms_per_tok
                        / 1e3;
                    recompute_s < fetch_s
                }
            }
            _ => false,
        };
        if rescue {
            rescue_spent = true;
            let lr = local.as_mut().expect("rescue implies a feeder");
            log_debug!(
                "fabric",
                "rescuing {} orphaned chunks onto local recompute",
                unfed.len()
            );
            chunks_recomputed += feed_local(lr, &unfed, read_seed(), &asm_cell);
            unfed = read_unfed()?;
            if unfed.is_empty() {
                break;
            }
        }
        if live.is_empty() {
            return None;
        }
        if budget_spent {
            log_debug!("fabric", "re-plan budget exhausted, {} chunks orphaned", unfed.len());
            return None;
        }
        rounds += 1;
        assign = planner.reassign(&unfed, &live);
        if assign.is_empty() {
            return None;
        }
        re_plans += 1;
        log_debug!(
            "fabric",
            "re-plan round {rounds}: {} orphaned chunks over {} survivors",
            unfed.len(),
            live.len()
        );
    }

    let asm = asm_cell.into_inner().unwrap_or(None)?;
    let head_peer = claimers[head_slot].0;
    finish_fetch(
        asm,
        wire_total,
        head_peer,
        sources.len() > 1,
        re_plans,
        share_failures,
        busy_shares,
        busy_replans,
        k - chunks_recomputed,
        chunks_recomputed,
    )
}

/// `GET` + verify + truncate an entire stored entry — the range path's
/// fallback and the legacy-alias path.  Returns the state truncated to `m`
/// rows, the wire bytes moved and the raw blob (for splice-base metadata).
pub fn fetch_full_entry(
    peer: &mut Peer,
    target: &[u8],
    m: usize,
    hash: &str,
    dims: (usize, usize, usize, usize),
) -> Option<(KvState, usize, SharedBytes)> {
    let t0 = Instant::now();
    let (fetched, dead) = {
        let Some((conn, shaper)) = peer.conn_parts() else {
            return None;
        };
        match shaper.shaped_post(|| {
            let r = conn.get(target);
            let n = r
                .as_ref()
                .map(|o| o.as_ref().map_or(0, |b| b.len()))
                .unwrap_or(0);
            (r, n)
        }) {
            Ok(opt) => (opt, None),
            Err(e) => {
                log_debug!("fabric", "full download failed: {e}");
                (None, Some(classify_io_err(&e)))
            }
        }
    };
    if let Some(o) = dead {
        peer.mark_dead_conn();
        peer.note_io(o);
    }
    let full = fetched?;
    peer.note_io(Outcome::IoOk);
    peer.ledger.bytes_down += full.len() as u64;
    peer.ledger.breakdown.add(Phase::Redis, t0.elapsed());
    match KvState::restore(&full, hash, dims) {
        Ok(mut state) if state.n_tokens >= m => {
            state.n_tokens = m;
            let wire = full.len();
            Some((state, wire, full))
        }
        Ok(_) => None,
        Err(e) => {
            log_debug!("fabric", "restore rejected: {e}");
            None
        }
    }
}

/// Outcome of one ring-driven repair sweep over an entry's designated
/// owners ([`repair_entry`]).
#[derive(Debug, Default, Clone, Copy)]
pub struct RepairOutcome {
    /// EXISTS probes attempted (one per owner in the sweep).
    pub probes: u64,
    /// Owners found missing the entry and successfully re-published to.
    pub republished: u64,
    /// Owners that turned out unreachable — membership changed under the
    /// caller, who should recompute the owner set and sweep once more.
    pub dead: u64,
    /// Re-publishes a reachable owner *rejected* (e.g. an OOM error reply
    /// to the SET): the replica is still missing, so the caller must not
    /// record the owner set as verified.
    pub rejected: u64,
    /// Payload wire bytes the re-publishes moved.
    pub wire: usize,
}

/// Ring-driven replica repair: EXISTS-probe each designated owner of
/// `store_key` and, where the entry is gone (a peer death took a copy, or
/// an eviction dropped it), re-publish `blob` and register `catalog_key`
/// on the box and in the peer's local catalog.  `blob` is built lazily —
/// a sweep that finds every owner intact serializes and ships nothing.
///
/// This is how replica bookkeeping is *derived from the ring* instead of
/// stored per entry: any client that can fetch an entry can recompute its
/// owner set and restore the replication factor, no matter who uploaded
/// the original copies.  Probes land in each peer's
/// `PeerLedger::fallback_probes` (they are catalog-less probes) and
/// re-publishes in `PeerLedger::repair_republishes`.
pub fn repair_entry(
    peers: &mut [Peer],
    owners: &[usize],
    store_key: &[u8],
    catalog_key: Option<&[u8]>,
    blob: &mut dyn FnMut() -> SharedBytes,
) -> RepairOutcome {
    let mut out = RepairOutcome::default();
    for &i in owners {
        let Some(peer) = peers.get_mut(i) else { continue };
        out.probes += 1;
        peer.ledger.fallback_probes += 1;
        let t0 = Instant::now();
        let probe = {
            let Some((conn, shaper)) = peer.conn_parts() else {
                peer.note_io(Outcome::IoDead);
                out.dead += 1;
                continue;
            };
            shaper.shaped(0, || conn.exists(store_key))
        };
        match probe {
            Ok(true) => {
                peer.note_io(Outcome::IoOk);
                peer.ledger.breakdown.add(Phase::Redis, t0.elapsed());
                continue; // this owner still serves the entry
            }
            Ok(false) => {}
            Err(e) => {
                log_debug!("fabric", "repair probe of {} failed: {e}", peer.cfg.addr);
                peer.mark_dead_conn();
                peer.note_io(classify_io_err(&e));
                peer.ledger.breakdown.add(Phase::Redis, t0.elapsed());
                out.dead += 1;
                continue;
            }
        }
        let b = blob();
        let blen = b.len();
        let mut reqs = Vec::with_capacity(2);
        reqs.push(request_shared(vec![
            SharedBytes::copy_from(b"SET"),
            store_key.to_vec().into(),
            b,
        ]));
        if let Some(ck) = catalog_key {
            reqs.push(request_shared(vec![
                SharedBytes::copy_from(b"CAT.REGISTER"),
                ck.to_vec().into(),
            ]));
        }
        let sent = {
            let Some((conn, shaper)) = peer.conn_parts() else {
                peer.note_io(Outcome::IoDead);
                out.dead += 1;
                peer.ledger.breakdown.add(Phase::Redis, t0.elapsed());
                continue;
            };
            shaper.shaped(blen, || conn.pipeline_req(&reqs))
        };
        match sent {
            // a transport-level Ok still carries per-command replies: a
            // box at its memory limit answers the SET with an OOM error,
            // and counting that as a repair would memoize a still-missing
            // replica (and register a claim the box cannot serve)
            Ok(replies) if replies.iter().any(|r| matches!(r, Value::Error(_))) => {
                log_debug!(
                    "fabric",
                    "repair publish to {} rejected by the box",
                    peer.cfg.addr
                );
                out.rejected += 1;
            }
            Ok(_) => {
                peer.note_io(Outcome::IoOk);
                peer.ledger.bytes_up += blen as u64;
                peer.ledger.repair_republishes += 1;
                peer.ledger.placed_entries += 1;
                out.republished += 1;
                out.wire += blen;
                if let Some(ck) = catalog_key {
                    if let Ok(mut cat) = peer.catalog.lock() {
                        cat.register_key(ck);
                    }
                }
                log_debug!(
                    "fabric",
                    "repaired entry onto {} ({} bytes)",
                    peer.cfg.addr,
                    blen
                );
            }
            Err(e) => {
                log_debug!("fabric", "repair publish to {} failed: {e}", peer.cfg.addr);
                peer.mark_dead_conn();
                peer.note_io(classify_io_err(&e));
                out.dead += 1;
            }
        }
        peer.ledger.breakdown.add(Phase::Redis, t0.elapsed());
    }
    out
}

/// The fabric's [`IndirectProbe`] implementation: before `Suspect → Dead`
/// is committed on circumstantial evidence, ask a third box to `PING` the
/// suspect (`PROBE.RELAY`) over *its* network path.  Relays are dialed
/// fresh with the probe budget's short deadlines — never through the
/// pooled request-path connections, which may themselves be mid-operation
/// on the thread that is asking — and the suspect is named by its gossip
/// identity, so a client reaching boxes through an interposer still asks
/// about the real address.  One positive answer suffices; relays that
/// cannot be reached or cannot say are skipped.
pub struct RelayProber {
    /// Dial address per fleet slot (what this client connects to).
    dial: Vec<String>,
    /// Gossip identity per fleet slot (what relays are asked to probe).
    identity: Vec<String>,
    budget: DeadlineBudget,
}

impl RelayProber {
    pub fn new(peers: &[PeerConfig], budget: DeadlineBudget) -> Self {
        RelayProber {
            dial: peers.iter().map(|p| p.addr.clone()).collect(),
            identity: peers
                .iter()
                .map(|p| p.gossip_identity().to_string())
                .collect(),
            budget,
        }
    }
}

impl IndirectProbe for RelayProber {
    fn probe_via(&self, vias: &[usize], target: usize) -> Option<bool> {
        let t = self.identity.get(target)?;
        for &v in vias {
            let Some(va) = self.dial.get(v) else { continue };
            let cfg = PeerConfig::new(va.clone()).with_deadline(self.budget);
            let Ok(mut conn) = cfg.dial() else { continue };
            match conn.probe_relay(t) {
                Ok(r) => return Some(r),
                Err(_) => continue, // an old box without the verb: try the next relay
            }
        }
        None
    }
}
