//! **Placement as an API** — every "where does this entry live" decision
//! behind one trait, so the upload path, the catalog-miss fallback and
//! replica repair all consult a single pluggable policy instead of
//! smearing placement knowledge across the fabric.
//!
//! Two implementations ship:
//!
//! * [`RendezvousRing`] — weighted highest-random-weight (HRW / rendezvous)
//!   hashing over the range key.  Placement is **deterministic fleet-wide**:
//!   any client that knows the peer addresses computes the same primary and
//!   the same k replica successors for a key, with no probe round trips at
//!   upload time.  That determinism is what makes the *residual* probe
//!   cheap and targeted — a client that rebooted with an empty Bloom
//!   catalog (or whose sync is lagging) can still find a warm entry by
//!   probing just the 1+k designated owners, and a fetch that discovers an
//!   owner missing an entry another owner serves knows exactly where the
//!   re-publish belongs ([`super::fabric::repair_entry`]).  HRW also moves
//!   a minimal key set on membership change: removing a node re-homes only
//!   the keys it owned (~K/n), every other key keeps its owner.
//! * [`PowerOfTwoChoices`] — the pre-existing load-probing policy
//!   ([`PeerPlanner::place`]): sample two peers, probe their `used_bytes`,
//!   keep the lighter.  Best-in-class byte balance, but it *forgets* where
//!   entries went — `owners` is empty, so catalog-less fallback probing and
//!   ring repair are unavailable.  Kept as a pluggable policy over the same
//!   sampling primitive; note equal-load ties now draw one extra bit from
//!   the seeded rng (see [`PeerPlanner::place`]), so sequences are
//!   reproducible per seed but not bit-identical to pre-trait builds.
//!
//! The trade-off the two span: p2c optimises byte balance at upload time
//! (2 probes per copy), the ring optimises recoverability (0 probes per
//! copy, bounded-probe lookup fallback, derivable replica sets) at the
//! cost of hash-balance instead of load-balance — see `benches/placement.rs`
//! for the measured gap on both axes.

use crate::coordinator::policy::PeerPlanner;
use crate::util::rng::Rng;

/// Caller-side peer index: the position of a peer in
/// `EdgeClientConfig::peers` (and in every `alive` slice handed to
/// [`Placement::on_membership_change`]).
pub type PeerId = usize;

/// A pluggable placement policy: where uploads land, which peers a
/// catalog-less lookup may probe, and which peers repair re-publishes to.
pub trait Placement: Send {
    /// Policy name for telemetry / CLI round-tripping.
    fn name(&self) -> &'static str;

    /// Whether [`Placement::owners`] is meaningful.  A deterministic
    /// policy supports catalog-less fallback probing and replica repair;
    /// a non-deterministic one (p2c) returns an empty owner set and those
    /// paths stay off.
    fn is_deterministic(&self) -> bool;

    /// Deterministic owner set for `key`: the primary first, then the
    /// `n_replicas` replica successors.  At most `1 + n_replicas` peers,
    /// never a duplicate, never a peer marked dead by the last membership
    /// update.  Empty when the policy has no deterministic owners.
    fn owners(&self, key: &[u8], n_replicas: usize) -> Vec<PeerId>;

    /// Upload-time placement: where the primary + `n_replicas` copies go,
    /// primary first.  `probe(peer)` reports the peer's current
    /// `used_bytes` (`u64::MAX` = unreachable); deterministic policies
    /// never call it.
    fn place_upload(
        &mut self,
        key: &[u8],
        n_replicas: usize,
        probe: &mut dyn FnMut(PeerId) -> u64,
    ) -> Vec<PeerId>;

    /// Membership update: `alive[i]` is peer `i`'s connectivity as the
    /// caller last observed it.  Dead peers drop out of owner sets (their
    /// successors take over) until marked alive again.
    fn on_membership_change(&mut self, alive: &[bool]);
}

/// Which [`Placement`] implementation a client config selects
/// (`--placement ring|p2c` on the CLI).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlacementKind {
    /// [`PowerOfTwoChoices`] — load-probing, non-deterministic.
    PowerOfTwoChoices,
    /// [`RendezvousRing`] — deterministic weighted HRW hashing.
    RendezvousRing,
}

impl PlacementKind {
    pub fn by_name(name: &str) -> Option<Self> {
        match name {
            "p2c" | "two-choices" | "power-of-two" => Some(Self::PowerOfTwoChoices),
            "ring" | "rendezvous" | "hrw" => Some(Self::RendezvousRing),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Self::PowerOfTwoChoices => "p2c",
            Self::RendezvousRing => "ring",
        }
    }
}

// ---------------------------------------------------------------------------
// RendezvousRing
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
struct RingNode {
    /// Stable fleet-wide identity (the peer's address).  Hashing the
    /// identity — not the caller-side index — is what makes two clients
    /// with differently-ordered peer lists agree on every owner set.
    ident: String,
    weight: f64,
    alive: bool,
}

/// Weighted rendezvous (HRW) hashing over stable node identities.
#[derive(Debug, Clone)]
pub struct RendezvousRing {
    nodes: Vec<RingNode>,
}

/// FNV-1a over `ident ++ len(ident) ++ key`, finished with a splitmix64
/// avalanche so nearby identities decorrelate.
fn hrw_hash(ident: &[u8], key: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in ident {
        h = (h ^ b as u64).wrapping_mul(0x100000001b3);
    }
    // length separator: "ab"+"c" must not collide with "a"+"bc"
    h = (h ^ ident.len() as u64).wrapping_mul(0x100000001b3);
    for &b in key {
        h = (h ^ b as u64).wrapping_mul(0x100000001b3);
    }
    h = (h ^ (h >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    h = (h ^ (h >> 27)).wrapping_mul(0x94D049BB133111EB);
    h ^ (h >> 31)
}

impl RendezvousRing {
    /// Uniform-weight ring over the given node identities (peer addrs).
    pub fn new<I: Into<String>>(idents: Vec<I>) -> Self {
        Self::weighted(idents.into_iter().map(|i| (i.into(), 1.0)).collect())
    }

    /// Weighted ring: a weight-2 node owns ~2× the keys of a weight-1
    /// node (classic weighted-rendezvous `-w / ln(u)` scoring).
    pub fn weighted(nodes: Vec<(String, f64)>) -> Self {
        RendezvousRing {
            nodes: nodes
                .into_iter()
                .map(|(ident, weight)| RingNode {
                    ident,
                    weight: if weight.is_finite() { weight.max(1e-9) } else { 1.0 },
                    alive: true,
                })
                .collect(),
        }
    }

    fn score(node: &RingNode, key: &[u8]) -> f64 {
        let h = hrw_hash(node.ident.as_bytes(), key);
        // u uniform in (0, 1]; ln(u) <= 0, so the score is positive and a
        // higher weight scales it up without breaking uniformity
        let u = ((h >> 11) as f64 + 1.0) * (1.0 / (1u64 << 53) as f64);
        -node.weight / u.ln().min(-1e-300)
    }

    /// Every live node ranked best-first for `key` — the full fallback
    /// order.  Ties (astronomically unlikely with f64 scores) break on the
    /// node identity so the ranking is independent of listing order.
    pub fn ranked(&self, key: &[u8]) -> Vec<PeerId> {
        let mut scored: Vec<(f64, PeerId)> = self
            .nodes
            .iter()
            .enumerate()
            .filter(|(_, n)| n.alive)
            .map(|(i, n)| (Self::score(n, key), i))
            .collect();
        scored.sort_by(|a, b| {
            b.0.partial_cmp(&a.0)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then_with(|| self.nodes[a.1].ident.cmp(&self.nodes[b.1].ident))
        });
        scored.into_iter().map(|(_, i)| i).collect()
    }
}

impl Placement for RendezvousRing {
    fn name(&self) -> &'static str {
        "ring"
    }

    fn is_deterministic(&self) -> bool {
        true
    }

    fn owners(&self, key: &[u8], n_replicas: usize) -> Vec<PeerId> {
        let mut r = self.ranked(key);
        r.truncate(1 + n_replicas);
        r
    }

    /// Deterministic placement never probes: the owner set *is* the
    /// target set, and a dead owner's slot falls to its ring successor
    /// (already handled by the alive filter in [`RendezvousRing::ranked`]).
    fn place_upload(
        &mut self,
        key: &[u8],
        n_replicas: usize,
        _probe: &mut dyn FnMut(PeerId) -> u64,
    ) -> Vec<PeerId> {
        self.owners(key, n_replicas)
    }

    fn on_membership_change(&mut self, alive: &[bool]) {
        for (node, &a) in self.nodes.iter_mut().zip(alive) {
            node.alive = a;
        }
    }
}

// ---------------------------------------------------------------------------
// PowerOfTwoChoices
// ---------------------------------------------------------------------------

/// The historical load-probing policy behind the [`Placement`] trait:
/// each copy is placed by [`PeerPlanner::place`] (two sampled peers, the
/// lighter `used_bytes` wins) over the live candidates not yet holding
/// one.  Owns its seeded [`Rng`], so a given seed replays the exact same
/// placement sequence — equal-load ties included (they draw from the
/// same rng; see [`PeerPlanner::place`]).
pub struct PowerOfTwoChoices {
    planner: PeerPlanner,
    rng: Rng,
    alive: Vec<bool>,
}

impl PowerOfTwoChoices {
    pub fn new(n_peers: usize, planner: PeerPlanner, seed: u64) -> Self {
        PowerOfTwoChoices { planner, rng: Rng::new(seed), alive: vec![true; n_peers] }
    }
}

impl Placement for PowerOfTwoChoices {
    fn name(&self) -> &'static str {
        "p2c"
    }

    fn is_deterministic(&self) -> bool {
        false
    }

    /// p2c keeps no map from keys to peers — there is no owner set to
    /// probe after a reboot, which is exactly the gap the ring closes.
    fn owners(&self, _key: &[u8], _n_replicas: usize) -> Vec<PeerId> {
        Vec::new()
    }

    fn place_upload(
        &mut self,
        _key: &[u8],
        n_replicas: usize,
        probe: &mut dyn FnMut(PeerId) -> u64,
    ) -> Vec<PeerId> {
        let mut out: Vec<PeerId> = Vec::with_capacity(1 + n_replicas);
        for _ in 0..=n_replicas {
            // dead-marked peers drop out of the candidate pool — sampling
            // them would spend a redial attempt plus a doomed INFO probe
            // before the planner discarded them anyway
            let candidates: Vec<PeerId> = (0..self.alive.len())
                .filter(|i| self.alive[*i] && !out.contains(i))
                .collect();
            if candidates.is_empty() {
                break;
            }
            match self.planner.place(&mut self.rng, &candidates, &mut *probe) {
                Some(i) => out.push(i),
                None => break, // both probes dead: caller salvages elsewhere
            }
        }
        out
    }

    fn on_membership_change(&mut self, alive: &[bool]) {
        self.alive = alive.to_vec();
    }
}

/// Zero-sized placeholder swapped into the client while the real policy is
/// temporarily moved out for a placement call that must also borrow the
/// peer table.  Places nothing, owns nothing.
pub struct Unplaced;

impl Placement for Unplaced {
    fn name(&self) -> &'static str {
        "unplaced"
    }

    fn is_deterministic(&self) -> bool {
        false
    }

    fn owners(&self, _key: &[u8], _n_replicas: usize) -> Vec<PeerId> {
        Vec::new()
    }

    fn place_upload(
        &mut self,
        _key: &[u8],
        _n_replicas: usize,
        _probe: &mut dyn FnMut(PeerId) -> u64,
    ) -> Vec<PeerId> {
        Vec::new()
    }

    fn on_membership_change(&mut self, _alive: &[bool]) {}
}

#[cfg(test)]
mod tests {
    use super::*;

    fn synth_keys(n: usize, seed: u64) -> Vec<[u8; 16]> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| {
                let mut k = [0u8; 16];
                for b in k.iter_mut() {
                    *b = rng.below(256) as u8;
                }
                k
            })
            .collect()
    }

    fn ring(n: usize) -> RendezvousRing {
        RendezvousRing::new((0..n).map(|i| format!("peer-{i}:760{i}")).collect())
    }

    #[test]
    fn balance_within_bound_across_synthetic_keys() {
        // 256 uniform keys over 4 uniform nodes: every node's primary
        // count stays within [mean/2, 1.5*mean] (the bound README states;
        // 3 sigma at this population is well inside it)
        let r = ring(4);
        let keys = synth_keys(256, 11);
        let mut counts = [0usize; 4];
        for k in &keys {
            counts[r.owners(k, 0)[0]] += 1;
        }
        let mean = keys.len() as f64 / 4.0;
        for (i, &c) in counts.iter().enumerate() {
            assert!(
                (c as f64) >= mean * 0.5 && (c as f64) <= mean * 1.5,
                "node {i} count {c} outside [{}, {}]: {counts:?}",
                mean * 0.5,
                mean * 1.5
            );
        }
    }

    #[test]
    fn weighted_nodes_own_proportional_key_shares() {
        // weight 3 vs three weight-1 nodes: the heavy node owns ~3x what
        // any light node does (weighted-rendezvous proportionality)
        let r = RendezvousRing::weighted(vec![
            ("heavy:1".into(), 3.0),
            ("a:2".into(), 1.0),
            ("b:3".into(), 1.0),
            ("c:4".into(), 1.0),
        ]);
        let keys = synth_keys(600, 13);
        let mut counts = [0usize; 4];
        for k in &keys {
            counts[r.owners(k, 0)[0]] += 1;
        }
        // expected 300 / 100 / 100 / 100
        let heavy = counts[0] as f64;
        let light = *counts[1..].iter().max().unwrap() as f64;
        assert!(
            heavy / light > 2.0 && heavy / light < 4.5,
            "weight-3 share off: {counts:?}"
        );
    }

    #[test]
    fn minimal_key_movement_on_leave_and_join() {
        let keys = synth_keys(300, 17);
        // leave: killing node 2 re-homes exactly the keys it owned
        let mut r = ring(5);
        let before: Vec<PeerId> = keys.iter().map(|k| r.owners(k, 0)[0]).collect();
        let mut alive = [true; 5];
        alive[2] = false;
        r.on_membership_change(&alive);
        let mut moved = 0usize;
        for (k, &old) in keys.iter().zip(&before) {
            let new = r.owners(k, 0)[0];
            if old == 2 {
                assert_ne!(new, 2, "dead node must not own keys");
                moved += 1;
            } else {
                assert_eq!(new, old, "survivor-owned keys must not move");
            }
        }
        let expect = keys.len() as f64 / 5.0;
        assert!(
            (moved as f64) > expect * 0.4 && (moved as f64) < expect * 2.5,
            "~K/n keys move on a leave, got {moved} of {}",
            keys.len()
        );

        // join: adding a 6th node moves only the keys it now wins
        let r5 = ring(5);
        let r6 = ring(6);
        let mut joined = 0usize;
        for k in &keys {
            let (old, new) = (r5.owners(k, 0)[0], r6.owners(k, 0)[0]);
            if new != old {
                assert_eq!(new, 5, "a moved key must have moved to the joiner");
                joined += 1;
            }
        }
        let expect = keys.len() as f64 / 6.0;
        assert!(
            (joined as f64) > expect * 0.4 && (joined as f64) < expect * 2.5,
            "~K/n keys move on a join, got {joined} of {}",
            keys.len()
        );
    }

    #[test]
    fn replica_sets_sized_deduped_and_never_dead() {
        let mut r = ring(5);
        let mut alive = [true; 5];
        alive[3] = false;
        r.on_membership_change(&alive);
        for k in synth_keys(120, 19) {
            let owners = r.owners(&k, 2);
            assert_eq!(owners.len(), 3, "primary + 2 successors");
            let mut dedup = owners.clone();
            dedup.sort_unstable();
            dedup.dedup();
            assert_eq!(dedup.len(), owners.len(), "no duplicate owners");
            assert!(!owners.contains(&3), "dead peers never own");
            assert_eq!(owners, r.owners(&k, 2), "deterministic across calls");
        }
        // replica demand beyond the live fleet clamps to the live fleet
        let owners = r.owners(b"whatever", 10);
        assert_eq!(owners.len(), 4);
    }

    #[test]
    fn owner_sets_independent_of_node_listing_order() {
        // two clients listing the same fleet in different orders must agree
        // on every owner *identity* — determinism is fleet-wide, not
        // per-client
        let idents = ["a:1", "b:2", "c:3", "d:4"];
        let fwd = RendezvousRing::new(idents.to_vec());
        let rev = RendezvousRing::new(idents.iter().rev().cloned().collect());
        for k in synth_keys(64, 23) {
            let f: Vec<&str> = fwd.owners(&k, 1).into_iter().map(|i| idents[i]).collect();
            let r: Vec<&str> = rev
                .owners(&k, 1)
                .into_iter()
                .map(|i| idents[idents.len() - 1 - i])
                .collect();
            assert_eq!(f, r, "owner identities must not depend on listing order");
        }
    }

    #[test]
    fn p2c_has_no_owners_but_places_distinct_copies() {
        let mut p = PowerOfTwoChoices::new(4, PeerPlanner::default(), 7);
        assert!(!p.is_deterministic());
        assert!(p.owners(b"k", 2).is_empty());
        let loads = [100u64, 5, 900, 40];
        let targets = p.place_upload(b"k", 2, &mut |i| loads[i]);
        assert_eq!(targets.len(), 3);
        let mut d = targets.clone();
        d.sort_unstable();
        d.dedup();
        assert_eq!(d.len(), 3, "copies land on distinct peers: {targets:?}");
        // replica demand beyond the fleet clamps to the fleet
        let targets = p.place_upload(b"k", 10, &mut |i| loads[i]);
        assert_eq!(targets.len(), 4);
        // an all-dead fleet places nothing
        let none = p.place_upload(b"k", 1, &mut |_| u64::MAX);
        assert!(none.is_empty());
        // dead-marked peers drop out of the candidate pool entirely — no
        // doomed samples, no wasted probes
        let mut alive = vec![true; 4];
        alive[2] = false;
        p.on_membership_change(&alive);
        for _ in 0..16 {
            let t = p.place_upload(b"k", 2, &mut |i| loads[i]);
            assert!(!t.contains(&2), "dead peer must never be placed on: {t:?}");
            assert_eq!(t.len(), 3, "three live peers take the three copies");
        }
        // revival restores the full pool
        p.on_membership_change(&[true; 4]);
        assert_eq!(p.place_upload(b"k", 3, &mut |i| loads[i]).len(), 4);
    }

    #[test]
    fn p2c_sequences_reproducible_under_seed() {
        let seq = |seed: u64| -> Vec<Vec<PeerId>> {
            let mut p = PowerOfTwoChoices::new(3, PeerPlanner::default(), seed);
            (0..32).map(|_| p.place_upload(b"x", 1, &mut |_| 7)).collect()
        };
        assert_eq!(seq(42), seq(42), "same seed, same placement sequence");
        assert_ne!(seq(42), seq(43), "different seed, different sequence");
    }

    #[test]
    fn kind_round_trips_by_name() {
        for k in [PlacementKind::PowerOfTwoChoices, PlacementKind::RendezvousRing] {
            assert_eq!(PlacementKind::by_name(k.name()), Some(k));
        }
        assert_eq!(PlacementKind::by_name("ring"), Some(PlacementKind::RendezvousRing));
        assert_eq!(PlacementKind::by_name("hrw"), Some(PlacementKind::RendezvousRing));
        assert_eq!(PlacementKind::by_name("p2c"), Some(PlacementKind::PowerOfTwoChoices));
        assert!(PlacementKind::by_name("consistent").is_none());
    }
}
