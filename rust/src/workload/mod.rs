//! MMLU-like workload generator (the dataset substitute).
//!
//! The paper evaluates on MMLU: 57 domains, each prompt = shared instruction
//! + N few-shot QA examples (fixed per domain, from the val split) + a target
//! question (from the test split), filtered to QA pairs of ≤256 words; 6434
//! prompts total.  The experiments exercise two properties of this dataset —
//! *shared prefixes within a domain* and the *length distribution* — both of
//! which this deterministic generator preserves (DESIGN.md §Substitutions):
//!
//! * per domain, the instruction and the N examples are fixed (seeded from
//!   the domain name), so all prompts in a domain share the Case-4 prefix;
//! * questions are templated from per-domain term banks with enough length
//!   variance to exercise the ≤256-word filter;
//! * every part boundary falls on whitespace, so tokenization is
//!   prefix-stable across the catalog's four ranges (Figure 3).

use crate::util::rng::Rng;

pub mod perturb;

/// The 57 MMLU subject domains (Hendrycks et al., ICLR'21).
pub const DOMAINS: [&str; 57] = [
    "abstract_algebra", "anatomy", "astronomy", "business_ethics",
    "clinical_knowledge", "college_biology", "college_chemistry",
    "college_computer_science", "college_mathematics", "college_medicine",
    "college_physics", "computer_security", "conceptual_physics",
    "econometrics", "electrical_engineering", "elementary_mathematics",
    "formal_logic", "global_facts", "high_school_biology",
    "high_school_chemistry", "high_school_computer_science",
    "high_school_european_history", "high_school_geography",
    "high_school_government_and_politics", "high_school_macroeconomics",
    "high_school_mathematics", "high_school_microeconomics",
    "high_school_physics", "high_school_psychology", "high_school_statistics",
    "high_school_us_history", "high_school_world_history", "human_aging",
    "human_sexuality", "international_law", "jurisprudence",
    "logical_fallacies", "machine_learning", "management", "marketing",
    "medical_genetics", "miscellaneous", "moral_disputes", "moral_scenarios",
    "nutrition", "philosophy", "prehistory", "professional_accounting",
    "professional_law", "professional_medicine", "professional_psychology",
    "public_relations", "security_studies", "sociology", "us_foreign_policy",
    "virology", "world_religions",
];

/// Generic term banks; combined with the domain name so each domain gets a
/// distinct but plausible vocabulary.
const SUBJECTS: &[&str] = &[
    "the fundamental principle", "the standard model", "a conserved quantity",
    "the boundary condition", "an equilibrium state", "the control group",
    "a dominant allele", "the supreme court", "an open market",
    "the prime factorization", "a feedback loop", "the observed sample",
    "an isolated system", "the underlying mechanism", "a regulatory pathway",
    "the historical record", "an early civilization", "the governing equation",
    "a second-order effect", "the limiting case",
];

const RELATIONS: &[&str] = &[
    "is best described by", "directly determines", "is independent of",
    "varies inversely with", "is a necessary condition for",
    "can be derived from", "is measured relative to", "contradicts",
    "is proportional to", "emerges from the interaction of",
];

const OBJECTS: &[&str] = &[
    "the rate of change observed in the system",
    "the total energy available to the process",
    "the distribution of outcomes across trials",
    "the structure imposed by the governing rules",
    "the response measured under controlled conditions",
    "the long-run behaviour of the population",
    "the set of admissible solutions",
    "the precedent established in earlier cases",
    "the marginal cost of one additional unit",
    "the stability of the resulting configuration",
];

const FILLERS: &[&str] = &[
    "in the general case", "under standard assumptions",
    "according to the prevailing theory", "as discussed in the literature",
    "for sufficiently large samples", "in the absence of external forcing",
    "when boundary effects are negligible", "across all measured regimes",
];

/// Short answer-option phrases (kept terse so N=5 prompts land near the
/// paper's 405-token astronomy prompt despite our coarser tokenizer).
const CHOICES: &[&str] = &[
    "the rate of change", "the total energy", "the sample distribution",
    "the governing rules", "the measured response", "the population trend",
    "the admissible set", "the earlier precedent", "the marginal cost",
    "the stable configuration", "an unrelated factor", "none of the above",
];

const ANSWER_LETTERS: [char; 4] = ['A', 'B', 'C', 'D'];

/// One multiple-choice QA pair.
#[derive(Debug, Clone, PartialEq)]
pub struct QaPair {
    pub question: String,
    pub choices: [String; 4],
    /// index into `choices` (0..4)
    pub answer: usize,
}

impl QaPair {
    /// Render as an answered few-shot example (MMLU harness format).
    pub fn as_example(&self) -> String {
        format!(
            "{}\nA. {}\nB. {}\nC. {}\nD. {}\nAnswer: {}\n\n",
            self.question,
            self.choices[0],
            self.choices[1],
            self.choices[2],
            self.choices[3],
            ANSWER_LETTERS[self.answer]
        )
    }

    /// Render as the target question (answer left for the model).
    pub fn as_target(&self) -> String {
        format!(
            "{}\nA. {}\nB. {}\nC. {}\nD. {}\nAnswer:",
            self.question, self.choices[0], self.choices[1], self.choices[2],
            self.choices[3]
        )
    }

    pub fn word_count(&self) -> usize {
        self.question.split_whitespace().count()
            + self.choices.iter().map(|c| c.split_whitespace().count()).sum::<usize>()
    }
}

fn domain_seed(domain: &str, global_seed: u64) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in domain.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h ^ global_seed
}

fn gen_question(rng: &mut Rng, domain: &str, length_boost: usize) -> QaPair {
    let topic = domain.replace('_', " ");
    let subj = rng.pick(SUBJECTS);
    let rel = rng.pick(RELATIONS);
    let obj = rng.pick(OBJECTS);
    let mut q = format!("In {topic}, {subj} {rel} {obj}");
    for _ in 0..length_boost {
        q.push_str(", ");
        q.push_str(*rng.pick(FILLERS));
    }
    q.push('?');

    let mut choices: [String; 4] = Default::default();
    let mut used = [false; 64];
    for c in choices.iter_mut() {
        // distinct short options
        loop {
            let i = rng.below(CHOICES.len() as u64) as usize;
            if !used[i] {
                used[i] = true;
                *c = CHOICES[i].to_string();
                break;
            }
        }
    }
    let answer = rng.below(4) as usize;
    QaPair { question: q, choices, answer }
}

/// A fully-assembled prompt with its logical structure exposed — the unit the
/// coordinator registers/looks up through the catalog's four ranges.
#[derive(Debug, Clone)]
pub struct Prompt {
    pub domain: String,
    pub instruction: String,
    /// Few-shot examples, already rendered (answered) — fixed per domain.
    pub examples: Vec<String>,
    /// The rendered target question.
    pub target: String,
    /// Ground-truth answer letter (for sanity accounting only).
    pub answer: char,
}

impl Prompt {
    pub fn full_text(&self) -> String {
        let mut s = self.instruction.clone();
        for e in &self.examples {
            s.push_str(e);
        }
        s.push_str(&self.target);
        s
    }

    /// The paper's Figure-3 prefix ranges, shortest → longest:
    /// 1) instruction, 2) instruction + first example,
    /// 3) instruction + all examples, 4) the entire prompt.
    /// (Deduplicated when N ≤ 1 makes ranges coincide.)
    pub fn prefix_texts(&self) -> Vec<String> {
        let mut out = Vec::with_capacity(4);
        out.push(self.instruction.clone());
        if !self.examples.is_empty() {
            let mut with_first = self.instruction.clone();
            with_first.push_str(&self.examples[0]);
            if self.examples.len() > 1 {
                out.push(with_first.clone());
                let mut with_all = with_first;
                for e in &self.examples[1..] {
                    with_all.push_str(e);
                }
                out.push(with_all);
            } else {
                out.push(with_first);
            }
        }
        out.push(self.full_text());
        out.dedup();
        out
    }

    pub fn word_count(&self) -> usize {
        self.full_text().split_whitespace().count()
    }
}

/// Deterministic MMLU-like dataset generator.
pub struct Generator {
    pub seed: u64,
    /// Max words per QA pair (the paper filters at 256).
    pub max_qa_words: usize,
}

impl Generator {
    pub fn new(seed: u64) -> Self {
        Generator { seed, max_qa_words: 256 }
    }

    pub fn instruction(&self, domain: &str) -> String {
        format!(
            "The following are multiple choice questions (with answers) about {}.\n\n",
            domain.replace('_', " ")
        )
    }

    /// The fixed few-shot examples of a domain (the paper's val-split draw).
    pub fn examples(&self, domain: &str, n_shots: usize) -> Vec<String> {
        let mut rng = Rng::new(domain_seed(domain, self.seed) ^ 0xE0A1);
        (0..n_shots)
            .map(|_| {
                let boost = rng.below(3) as usize;
                self.bounded_qa(&mut rng, domain, boost).as_example()
            })
            .collect()
    }

    /// The i-th test question of a domain.
    pub fn question(&self, domain: &str, index: u64) -> QaPair {
        let mut rng = Rng::new(domain_seed(domain, self.seed) ^ (0xBEEF + index));
        let boost = rng.below(6) as usize;
        self.bounded_qa(&mut rng, domain, boost)
    }

    fn bounded_qa(&self, rng: &mut Rng, domain: &str, boost: usize) -> QaPair {
        // regenerate with shrinking boost until the ≤max_qa_words filter holds
        let mut b = boost;
        loop {
            let qa = gen_question(rng, domain, b);
            if qa.word_count() <= self.max_qa_words {
                return qa;
            }
            b = b.saturating_sub(1);
        }
    }

    /// Assemble the full prompt for (domain, question index, N shots).
    pub fn prompt(&self, domain: &str, index: u64, n_shots: usize) -> Prompt {
        let qa = self.question(domain, index);
        Prompt {
            domain: domain.to_string(),
            instruction: self.instruction(domain),
            examples: self.examples(domain, n_shots),
            target: qa.as_target(),
            answer: ANSWER_LETTERS[qa.answer],
        }
    }
}

/// One query in a multi-client trace.
#[derive(Debug, Clone)]
pub struct Query {
    pub client: usize,
    pub domain: String,
    pub question_index: u64,
    pub n_shots: usize,
}

/// A reproducible multi-client query trace over the 57 domains.
pub struct Trace {
    pub queries: Vec<Query>,
}

impl Trace {
    /// `n_domains` domains × `per_domain` questions, shuffled and dealt
    /// round-robin-randomly to `n_clients` clients.
    pub fn generate(
        seed: u64,
        n_clients: usize,
        n_domains: usize,
        per_domain: usize,
        n_shots: usize,
    ) -> Trace {
        assert!(n_domains <= DOMAINS.len());
        let mut rng = Rng::new(seed ^ 0x7ACE);
        let mut queries = Vec::with_capacity(n_domains * per_domain);
        for &domain in DOMAINS.iter().take(n_domains) {
            for q in 0..per_domain {
                queries.push(Query {
                    client: rng.below(n_clients.max(1) as u64) as usize,
                    domain: domain.to_string(),
                    question_index: q as u64,
                    n_shots,
                });
            }
        }
        rng.shuffle(&mut queries);
        Trace { queries }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifty_seven_domains() {
        assert_eq!(DOMAINS.len(), 57);
        let mut d = DOMAINS.to_vec();
        d.sort_unstable();
        d.dedup();
        assert_eq!(d.len(), 57, "domains must be unique");
        assert!(DOMAINS.contains(&"astronomy")); // the Table-4 domain
    }

    #[test]
    fn deterministic_generation() {
        let a = Generator::new(7).prompt("astronomy", 3, 5);
        let b = Generator::new(7).prompt("astronomy", 3, 5);
        assert_eq!(a.full_text(), b.full_text());
        let c = Generator::new(8).prompt("astronomy", 3, 5);
        assert_ne!(a.full_text(), c.full_text(), "seed must matter");
    }

    #[test]
    fn examples_fixed_per_domain_questions_vary() {
        let g = Generator::new(1);
        let p1 = g.prompt("anatomy", 0, 5);
        let p2 = g.prompt("anatomy", 1, 5);
        assert_eq!(p1.instruction, p2.instruction);
        assert_eq!(p1.examples, p2.examples, "shared prefix within domain");
        assert_ne!(p1.target, p2.target);
        let p3 = g.prompt("virology", 0, 5);
        assert_ne!(p1.examples, p3.examples, "examples differ across domains");
    }

    #[test]
    fn prefix_ranges_are_nested_prefixes() {
        let g = Generator::new(2);
        let p = g.prompt("astronomy", 0, 5);
        let ranges = p.prefix_texts();
        assert_eq!(ranges.len(), 4, "N=5 yields all four Figure-3 ranges");
        for w in ranges.windows(2) {
            assert!(w[1].starts_with(&w[0]), "ranges must nest");
            assert!(w[1].len() > w[0].len());
        }
        assert_eq!(*ranges.last().unwrap(), p.full_text());
    }

    #[test]
    fn prefix_ranges_degenerate_cases() {
        let g = Generator::new(2);
        let p1 = g.prompt("anatomy", 0, 1); // N=1: instr, instr+ex1, full
        assert_eq!(p1.prefix_texts().len(), 3);
        let p0 = g.prompt("anatomy", 0, 0); // N=0: instr, full
        assert_eq!(p0.prefix_texts().len(), 2);
    }

    #[test]
    fn qa_word_filter_respected() {
        let mut g = Generator::new(3);
        g.max_qa_words = 64;
        for i in 0..50 {
            let qa = g.question("philosophy", i);
            assert!(qa.word_count() <= 64, "{} words", qa.word_count());
        }
    }

    #[test]
    fn prompt_lengths_plausible() {
        // paper: astronomy N=5 prompt = 405 Gemma tokens ≈ 300 words
        let g = Generator::new(4);
        let p = g.prompt("astronomy", 0, 5);
        let w = p.word_count();
        assert!((120..=600).contains(&w), "N=5 prompt has {w} words");
        let p1 = g.prompt("astronomy", 0, 1);
        assert!(p1.word_count() < w);
    }

    #[test]
    fn example_format_matches_mmlu_harness() {
        let g = Generator::new(5);
        let p = g.prompt("college_physics", 0, 2);
        assert!(p.instruction.starts_with("The following are multiple choice"));
        assert!(p.instruction.contains("college physics"));
        for e in &p.examples {
            assert!(e.contains("\nA. ") && e.contains("\nD. "));
            assert!(e.contains("\nAnswer: "));
            assert!(e.ends_with("\n\n"));
        }
        assert!(p.target.ends_with("Answer:"));
    }

    #[test]
    fn trace_covers_all_clients_and_domains() {
        let t = Trace::generate(11, 3, 10, 20, 5);
        assert_eq!(t.queries.len(), 200);
        let mut clients = [false; 3];
        let mut domains = std::collections::HashSet::new();
        for q in &t.queries {
            clients[q.client] = true;
            domains.insert(q.domain.clone());
        }
        assert!(clients.iter().all(|&c| c));
        assert_eq!(domains.len(), 10);
    }

    #[test]
    fn trace_deterministic() {
        let a = Trace::generate(1, 2, 5, 5, 1);
        let b = Trace::generate(1, 2, 5, 5, 1);
        for (x, y) in a.queries.iter().zip(&b.queries) {
            assert_eq!(x.client, y.client);
            assert_eq!(x.domain, y.domain);
            assert_eq!(x.question_index, y.question_index);
        }
    }
}
