//! Seeded paraphrase perturbation for the MMLU-like workload — the
//! "semantically similar, textually different" traffic the semantic tier
//! (`crate::sketch`) is built for.
//!
//! Three composable edit families, all deterministic under a seed:
//!
//! * **synonym-bucket swaps** — each word whose lowercase core sits in one
//!   of the disjoint [`SYNONYM_BUCKETS`] is replaced by another member of
//!   its bucket with per-word probability `rate`.  Punctuation and
//!   whitespace survive, so tokenization stays word-aligned;
//! * **clause reorder** — with per-prompt probability `reorder`, two
//!   adjacent interior comma-clauses of the target question swap places
//!   (the generator's filler clauses are order-independent paraphrases);
//! * **prefix boilerplate jitter** — with per-prompt probability
//!   `prefix_jitter`, a boilerplate sentence is *prepended* to the
//!   instruction.  This is the adversarial mode: it keeps the sketch close
//!   while destroying the common token prefix, which is exactly the shape
//!   the verification gate must catch (a false probe, never a reuse).
//!
//! A swap early in the prompt defeats every exact catalog range (total
//! miss) while leaving the sketch within a few bits of the original — the
//! regime where nearest-sketch search plus token-prefix verification
//! recovers real reuse that exact matching cannot see.

use crate::util::rng::Rng;
use crate::workload::Prompt;

/// Disjoint buckets of interchangeable words, biased toward the
/// generator's term banks so perturbation actually lands on real prompts.
pub const SYNONYM_BUCKETS: &[&[&str]] = &[
    &["fundamental", "foundational", "basic"],
    &["standard", "conventional", "typical"],
    &["observed", "measured", "recorded"],
    &["determines", "governs", "dictates"],
    &["described", "characterized", "captured"],
    &["total", "overall", "aggregate"],
    &["behaviour", "dynamics", "evolution"],
    &["questions", "problems", "items"],
    &["answers", "solutions", "responses"],
    &["general", "broad", "usual"],
    &["large", "big", "substantial"],
    &["conditions", "circumstances", "constraints"],
    &["rate", "pace", "tempo"],
    &["stability", "robustness", "steadiness"],
    &["following", "subsequent", "ensuing"],
    &["derived", "obtained", "deduced"],
];

/// Boilerplate sentences for the adversarial prefix-jitter mode.
pub const BOILERPLATE: &[&str] = &[
    "Answer with a single letter. ",
    "Read every option before answering. ",
    "Choose the best option. ",
];

/// Seeded paraphrase perturber; one instance = one reproducible stream of
/// edits.  For a per-query stable paraphrase, construct it from a seed
/// derived from the query identity.
pub struct Perturber {
    rng: Rng,
    /// Per-word synonym-swap probability (the bench's "perturbation rate").
    pub rate: f64,
    /// Per-prompt clause-reorder probability.
    pub reorder: f64,
    /// Per-prompt adversarial boilerplate-prepend probability (default 0 —
    /// opt in for verification-gate stress).
    pub prefix_jitter: f64,
}

impl Perturber {
    pub fn new(seed: u64, rate: f64) -> Self {
        Perturber {
            rng: Rng::new(seed ^ 0x5EED_9A9A),
            rate,
            reorder: rate,
            prefix_jitter: 0.0,
        }
    }

    /// Swap bucket words in `text` at the configured per-word rate.
    /// Word-structure preserving: only maximal alphabetic runs are
    /// considered, everything else is copied through verbatim.
    pub fn swap_synonyms(&mut self, text: &str) -> String {
        let mut out = String::with_capacity(text.len());
        let mut word = String::new();
        for ch in text.chars() {
            if ch.is_alphabetic() {
                word.push(ch);
            } else {
                self.flush_word(&mut out, &mut word);
                out.push(ch);
            }
        }
        self.flush_word(&mut out, &mut word);
        out
    }

    fn flush_word(&mut self, out: &mut String, word: &mut String) {
        if word.is_empty() {
            return;
        }
        let lower = word.to_lowercase();
        let hit = SYNONYM_BUCKETS.iter().find_map(|b| {
            b.iter().position(|w| **w == lower).map(|i| (*b, i))
        });
        match hit {
            Some((bucket, i)) if self.rng.chance(self.rate) => {
                // a different member, uniformly
                let j = (i + 1 + self.rng.below(bucket.len() as u64 - 1) as usize)
                    % bucket.len();
                let mut rep = bucket[j].to_string();
                if word.chars().next().is_some_and(|c| c.is_uppercase()) {
                    let mut cs = rep.chars();
                    rep = cs.next().map(|c| c.to_uppercase().collect::<String>())
                        .unwrap_or_default()
                        + cs.as_str();
                }
                out.push_str(&rep);
            }
            _ => out.push_str(word),
        }
        word.clear();
    }

    /// With probability `reorder`, swap two adjacent interior comma-clauses
    /// of the first line of `text` (the question sentence).  Lines after
    /// the first (the answer options) are never touched.
    pub fn reorder_clauses(&mut self, text: &str) -> String {
        if !self.rng.chance(self.reorder) {
            return text.to_string();
        }
        let (first, rest) = match text.split_once('\n') {
            Some((f, r)) => (f, Some(r)),
            None => (text, None),
        };
        let parts: Vec<&str> = first.split(", ").collect();
        let mut out = if parts.len() >= 3 {
            // interior adjacent pair: positions 1..len-1
            let i = 1 + self.rng.below(parts.len() as u64 - 2) as usize;
            let mut p = parts.clone();
            p.swap(i, i - 1);
            p.join(", ")
        } else {
            first.to_string()
        };
        if let Some(r) = rest {
            out.push('\n');
            out.push_str(r);
        }
        out
    }

    /// Apply the full family to a structured prompt: synonym swaps over
    /// every part, clause reorder over the target question, and (when
    /// enabled) adversarial boilerplate prepended to the instruction.
    pub fn perturb(&mut self, p: &Prompt) -> Prompt {
        let mut instruction = self.swap_synonyms(&p.instruction);
        if self.prefix_jitter > 0.0 && self.rng.chance(self.prefix_jitter) {
            let b = *self.rng.pick(BOILERPLATE);
            instruction = format!("{b}{instruction}");
        }
        let examples = p.examples.iter().map(|e| self.swap_synonyms(e)).collect();
        let target = self.swap_synonyms(&p.target);
        let target = self.reorder_clauses(&target);
        Prompt {
            domain: p.domain.clone(),
            instruction,
            examples,
            target,
            answer: p.answer,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::Generator;

    fn sample() -> Prompt {
        Generator::new(7).prompt("astronomy", 3, 5)
    }

    #[test]
    fn buckets_are_disjoint_and_plural() {
        let mut seen = std::collections::HashSet::new();
        for b in SYNONYM_BUCKETS {
            assert!(b.len() >= 2, "a bucket needs an alternative");
            for w in *b {
                assert!(seen.insert(*w), "{w} appears in two buckets");
                assert_eq!(**w, w.to_lowercase(), "buckets store lowercase");
            }
        }
    }

    #[test]
    fn zero_rate_is_identity() {
        let p = sample();
        let mut pert = Perturber::new(1, 0.0);
        let q = pert.perturb(&p);
        assert_eq!(p.full_text(), q.full_text());
    }

    #[test]
    fn deterministic_under_seed() {
        let p = sample();
        let a = Perturber::new(42, 0.5).perturb(&p);
        let b = Perturber::new(42, 0.5).perturb(&p);
        assert_eq!(a.full_text(), b.full_text());
        let c = Perturber::new(43, 0.5).perturb(&p);
        // overwhelmingly likely to differ at rate 0.5
        assert_ne!(a.full_text(), c.full_text());
    }

    #[test]
    fn high_rate_changes_text_but_preserves_shape() {
        let p = sample();
        let mut pert = Perturber::new(5, 1.0);
        pert.reorder = 0.0;
        let q = pert.perturb(&p);
        assert_ne!(p.full_text(), q.full_text());
        // word-structure preserving: same word count, same line count
        assert_eq!(p.word_count(), q.word_count());
        assert_eq!(
            p.full_text().lines().count(),
            q.full_text().lines().count()
        );
    }

    #[test]
    fn swaps_stay_inside_their_bucket() {
        let mut pert = Perturber::new(9, 1.0);
        let out = pert.swap_synonyms("the total rate observed under standard conditions");
        for (orig, new) in
            "the total rate observed under standard conditions".split(' ').zip(out.split(' '))
        {
            if orig == new {
                continue;
            }
            let bucket = SYNONYM_BUCKETS
                .iter()
                .find(|b| b.contains(&orig))
                .unwrap_or_else(|| panic!("{orig} changed but is in no bucket"));
            assert!(bucket.contains(&new), "{new} escaped {orig}'s bucket");
        }
    }

    #[test]
    fn prefix_jitter_prepends_boilerplate() {
        let p = sample();
        let mut pert = Perturber::new(3, 0.0);
        pert.prefix_jitter = 1.0;
        let q = pert.perturb(&p);
        assert!(BOILERPLATE.iter().any(|b| q.instruction.starts_with(b)));
        assert!(q.instruction.ends_with(&p.instruction));
    }

    #[test]
    fn reorder_preserves_clause_multiset() {
        let mut pert = Perturber::new(11, 0.0);
        pert.reorder = 1.0;
        let text = "alpha, beta, gamma, delta?\nA. x\nB. y";
        let out = pert.reorder_clauses(text);
        let (first, rest) = out.split_once('\n').unwrap();
        assert_eq!(rest, "A. x\nB. y", "options untouched");
        let mut orig: Vec<&str> = "alpha, beta, gamma, delta?".split(", ").collect();
        let mut got: Vec<&str> = first.split(", ").collect();
        orig.sort_unstable();
        got.sort_unstable();
        assert_eq!(orig, got, "reorder must be a permutation");
    }
}
