//! Device pacing — the Raspberry Pi substitute.
//!
//! The paper's clients are a Raspberry Pi Zero 2W (Cortex-A53 @1 GHz, 512 MB)
//! and a Raspberry Pi 5 (Cortex-A76 @2.4 GHz).  We execute the real model on
//! the host CPU, then *stretch* each compute phase to the target device's
//! calibrated per-token rates: a [`Pacer`] measures the real duration and
//! sleeps the remainder, so paced time = `max(real, modelled)` and every
//! logit is still genuinely computed.
//!
//! Rates are derived from paper Table 3 (ms, averaged over 6434 prompts):
//!
//! | device            | model | prefill/tok | decode/tok | sample/tok | tokenize/tok |
//! |-------------------|-------|------------:|-----------:|-----------:|-------------:|
//! | Pi Zero 2W (low)  | 270M  | 192.75      | 172.1      | 1.49       | 0.053        |
//! | Pi 5 4GB (high)   | 1B    | 8.046       | 72.59      | 1.45       | 0.0048       |
//!
//! (prefill/tok = P-decode 12580.85 ms ÷ 65.27 tokens, etc.  The low-end
//! R-decode of 11061 ms at 1.49 ms/sample implies ≈64 generated tokens —
//! the 270M model rambles; the 1B model answers in one token.)
//!
//! `DeviceProfile::host` disables pacing (native measurement mode).

use std::time::{Duration, Instant};

/// Calibrated per-phase costs of one device+model pairing.
#[derive(Debug, Clone)]
pub struct DeviceProfile {
    pub name: &'static str,
    /// ms of prefill compute per prompt token (P-decode rate).
    pub prefill_ms_per_tok: f64,
    /// ms of forward-pass compute per generated token (R-decode rate).
    pub decode_ms_per_tok: f64,
    /// ms to sample one token from the logits.
    pub sample_ms_per_tok: f64,
    /// ms to tokenize one prompt token.
    pub tokenize_ms_per_tok: f64,
    /// ms for one local catalog (Bloom) query batch.
    pub bloom_ms_per_lookup: f64,
    /// Typical generated-response length for this device's model (the paper's
    /// implied 64 tokens for 270M, 1 for 1B).
    pub typical_response_tokens: usize,
}

impl DeviceProfile {
    /// Raspberry Pi Zero 2W running Gemma-3-270M-class (paper low-end).
    pub fn pi_zero_2w() -> Self {
        DeviceProfile {
            name: "pi-zero-2w",
            prefill_ms_per_tok: 12580.85 / 65.27,
            decode_ms_per_tok: 11061.04 / 64.27,
            sample_ms_per_tok: 95.69 / 64.27,
            tokenize_ms_per_tok: 3.46 / 65.27,
            bloom_ms_per_lookup: 0.30,
            typical_response_tokens: 64,
        }
    }

    /// Raspberry Pi 5 (4 GB) running Gemma-3-1B-class (paper high-end).
    pub fn pi5_4gb() -> Self {
        DeviceProfile {
            name: "pi5-4gb",
            prefill_ms_per_tok: 2688.17 / 334.11,
            decode_ms_per_tok: 72.59,
            sample_ms_per_tok: 1.45,
            tokenize_ms_per_tok: 1.61 / 334.11,
            bloom_ms_per_lookup: 0.01,
            typical_response_tokens: 1,
        }
    }

    /// No pacing: report raw host performance.
    pub fn host() -> Self {
        DeviceProfile {
            name: "host",
            prefill_ms_per_tok: 0.0,
            decode_ms_per_tok: 0.0,
            sample_ms_per_tok: 0.0,
            tokenize_ms_per_tok: 0.0,
            bloom_ms_per_lookup: 0.0,
            typical_response_tokens: 8,
        }
    }

    pub fn by_name(name: &str) -> Option<Self> {
        match name {
            "pi-zero-2w" | "low-end" | "low" => Some(Self::pi_zero_2w()),
            "pi5-4gb" | "high-end" | "high" => Some(Self::pi5_4gb()),
            "host" | "native" | "none" => Some(Self::host()),
            _ => None,
        }
    }

    pub fn is_host(&self) -> bool {
        self.prefill_ms_per_tok == 0.0 && self.decode_ms_per_tok == 0.0
    }

    /// Whether this device's prefill side is modelled at all.  The host
    /// profile prefills at rate 0, which would make local recompute free
    /// under any cost model — per-chunk fetch planning
    /// (`coordinator::plan`) only engages when this holds, so native runs
    /// keep the historical all-fetch restore path.
    pub fn models_recompute(&self) -> bool {
        self.prefill_ms_per_tok > 0.0
    }

    // -- analytic model (no execution; used for full-population sweeps) -----

    pub fn prefill_time(&self, tokens: usize) -> Duration {
        Duration::from_secs_f64(self.prefill_ms_per_tok * tokens as f64 / 1e3)
    }

    pub fn decode_time(&self, tokens: usize) -> Duration {
        Duration::from_secs_f64(self.decode_ms_per_tok * tokens as f64 / 1e3)
    }

    pub fn sample_time(&self, tokens: usize) -> Duration {
        Duration::from_secs_f64(self.sample_ms_per_tok * tokens as f64 / 1e3)
    }

    pub fn tokenize_time(&self, tokens: usize) -> Duration {
        Duration::from_secs_f64(self.tokenize_ms_per_tok * tokens as f64 / 1e3)
    }

    pub fn bloom_time(&self, lookups: usize) -> Duration {
        Duration::from_secs_f64(self.bloom_ms_per_lookup * lookups as f64 / 1e3)
    }
}

/// Stretches real compute to a device's modelled duration.
#[derive(Debug, Clone)]
pub struct Pacer {
    pub profile: DeviceProfile,
    /// Total sleep injected (diagnostic: modelled − real).
    pub injected: Duration,
    /// Total real compute observed.
    pub real: Duration,
}

impl Pacer {
    pub fn new(profile: DeviceProfile) -> Self {
        Pacer { profile, injected: Duration::ZERO, real: Duration::ZERO }
    }

    /// Run `op` and stretch to `target`; returns op's output.
    pub fn paced<T>(&mut self, target: Duration, op: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = op();
        let real = t0.elapsed();
        self.real += real;
        if !self.profile.is_host() && real < target {
            let pad = target - real;
            std::thread::sleep(pad);
            self.injected += pad;
        }
        out
    }

    pub fn paced_prefill<T>(&mut self, tokens: usize, op: impl FnOnce() -> T) -> T {
        let t = self.profile.prefill_time(tokens);
        self.paced(t, op)
    }

    pub fn paced_decode<T>(&mut self, tokens: usize, op: impl FnOnce() -> T) -> T {
        let t = self.profile.decode_time(tokens);
        self.paced(t, op)
    }

    pub fn paced_sample<T>(&mut self, tokens: usize, op: impl FnOnce() -> T) -> T {
        let t = self.profile.sample_time(tokens);
        self.paced(t, op)
    }

    pub fn paced_tokenize<T>(&mut self, tokens_estimate: usize, op: impl FnOnce() -> T) -> T {
        let t = self.profile.tokenize_time(tokens_estimate);
        self.paced(t, op)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_table3_low_end_reconstruction() {
        // P-decode for the mean 65.27-token prompt must land on 12.58 s
        let p = DeviceProfile::pi_zero_2w();
        let t = p.prefill_time(65).as_secs_f64();
        assert!((12.3..12.8).contains(&t), "{t}");
        // R-decode + Sample for ~64 generated tokens ≈ 11.16 s
        let d = (p.decode_time(64) + p.sample_time(64)).as_secs_f64();
        assert!((10.8..11.4).contains(&d), "{d}");
    }

    #[test]
    fn paper_table3_high_end_reconstruction() {
        let p = DeviceProfile::pi5_4gb();
        let t = p.prefill_time(334).as_secs_f64();
        assert!((2.6..2.8).contains(&t), "{t}");
        let d = (p.decode_time(1) + p.sample_time(1)).as_secs_f64();
        assert!((0.07..0.08).contains(&d), "{d}");
    }

    #[test]
    fn low_end_much_slower_than_high_end_per_token() {
        let lo = DeviceProfile::pi_zero_2w();
        let hi = DeviceProfile::pi5_4gb();
        let ratio = lo.prefill_ms_per_tok / hi.prefill_ms_per_tok;
        // A53@1GHz w/ 270M vs A76@2.4GHz w/ 1B: paper implies ~24x per-token
        assert!((15.0..35.0).contains(&ratio), "ratio {ratio:.1}");
    }

    #[test]
    fn pacer_stretches_fast_ops() {
        let mut p = Pacer::new(DeviceProfile {
            name: "test",
            prefill_ms_per_tok: 10.0,
            decode_ms_per_tok: 0.0,
            sample_ms_per_tok: 0.0,
            tokenize_ms_per_tok: 0.0,
            bloom_ms_per_lookup: 0.0,
            typical_response_tokens: 1,
        });
        let t0 = Instant::now();
        let v = p.paced_prefill(5, || 7); // target 50 ms
        assert_eq!(v, 7);
        assert!(t0.elapsed() >= Duration::from_millis(49));
        assert!(p.injected >= Duration::from_millis(40));
    }

    #[test]
    fn host_profile_never_sleeps() {
        let mut p = Pacer::new(DeviceProfile::host());
        let t0 = Instant::now();
        p.paced_prefill(1000, || ());
        p.paced_decode(1000, || ());
        assert!(t0.elapsed() < Duration::from_millis(20));
        assert_eq!(p.injected, Duration::ZERO);
    }

    #[test]
    fn pacer_does_not_shrink_slow_ops() {
        let mut p = Pacer::new(DeviceProfile::pi5_4gb());
        let t0 = Instant::now();
        // target for 1 token ≈ 8 ms; op takes 30 ms
        p.paced_prefill(1, || std::thread::sleep(Duration::from_millis(30)));
        let el = t0.elapsed();
        assert!(el >= Duration::from_millis(30));
        assert!(el < Duration::from_millis(60));
    }

    #[test]
    fn by_name_aliases() {
        assert_eq!(DeviceProfile::by_name("low-end").unwrap().name, "pi-zero-2w");
        assert_eq!(DeviceProfile::by_name("high").unwrap().name, "pi5-4gb");
        assert!(DeviceProfile::by_name("host").unwrap().is_host());
        assert!(DeviceProfile::by_name("cray-1").is_none());
    }

    #[test]
    fn analytic_times_linear() {
        let p = DeviceProfile::pi_zero_2w();
        let t10 = p.prefill_time(10).as_secs_f64();
        let t20 = p.prefill_time(20).as_secs_f64();
        assert!((t20 / t10 - 2.0).abs() < 1e-9);
    }
}
