//! The local LLM inference engine — what runs *on* each edge device.
//!
//! Wraps a [`LoadedModel`] (PJRT executables + resident params) with:
//! chunked prefill over the AOT prefill variants, the single-token decode
//! loop, greedy/top-k sampling, KV-state snapshot/restore hooks and
//! six-phase latency attribution.  Device pacing ([`Pacer`]) stretches each
//! compute call to the calibrated Raspberry-Pi rates when a device profile
//! is active; on the `host` profile everything runs at native speed.
//!
//! The distributed-cache integration points are exactly two:
//! * [`Engine::prefill_suffix`] — prefill only the tokens a restored state
//!   does not already cover (partial-matching fast path, paper §3.2);
//! * [`Engine::first_logits`] — obtain first-token logits for a *fully*
//!   cached prompt by re-deriving the last prompt token's forward pass (one
//!   decode step; the cached state stores K/V, not logits).

use anyhow::{bail, Result};

use crate::devicemodel::Pacer;
use crate::metrics::{Phase, PhaseBreakdown};
use crate::model::sampler::Sampler;
use crate::model::state::KvState;
use crate::runtime::LoadedModel;
use crate::tokenizer::Tokenizer;

pub struct Engine {
    pub model: LoadedModel,
    pub tokenizer: Tokenizer,
    /// Stop generation at this token (tokenizer EOS).
    pub eos_token: u32,
}

/// Result of one full generate() call.
#[derive(Debug, Clone)]
pub struct GenOutput {
    pub prompt_tokens: usize,
    pub reused_tokens: usize,
    pub tokens: Vec<u32>,
    pub text: String,
    pub breakdown: PhaseBreakdown,
}

impl Engine {
    pub fn new(model: LoadedModel) -> Self {
        let budget = (model.config.vocab as u32).min(u32::MAX);
        let tokenizer = Tokenizer::with_budget(budget);
        Engine { model, tokenizer, eos_token: crate::tokenizer::EOS }
    }

    pub fn load_preset(preset: &str) -> Result<Self> {
        Ok(Self::new(LoadedModel::load_preset(preset)?))
    }

    pub fn fresh_state(&self) -> KvState {
        KvState::for_config(&self.model.config)
    }

    pub fn model_hash(&self) -> &str {
        &self.model.model_hash
    }

    /// Tokenize with BOS, clamped to leave room for generation.
    pub fn tokenize_prompt(&self, text: &str) -> Vec<u32> {
        let mut toks = self.tokenizer.encode_with_bos(text);
        let cap = self.model.config.max_seq.saturating_sub(8);
        toks.truncate(cap);
        toks
    }

    /// Pick the prefill chunk for `remaining` tokens: the smallest variant
    /// that covers it, else the largest available (loop again).
    fn pick_chunk(&self, remaining: usize) -> usize {
        let chunks = self.model.chunks();
        assert!(!chunks.is_empty(), "artifact has no prefill entries");
        for &c in &chunks {
            if c >= remaining {
                return c;
            }
        }
        *chunks.last().unwrap()
    }

    /// Prefill `tokens[state.n_tokens..]`, mutating `state`; returns the
    /// logits of the final valid token.  No-op (returns None) if the state
    /// already covers the whole prompt.
    pub fn prefill_suffix(
        &self,
        state: &mut KvState,
        tokens: &[u32],
        pacer: &mut Pacer,
        bd: &mut PhaseBreakdown,
    ) -> Result<Option<Vec<f32>>> {
        if state.n_tokens > tokens.len() {
            bail!(
                "state covers {} tokens but prompt has only {}",
                state.n_tokens,
                tokens.len()
            );
        }
        let mut last_logits: Option<Vec<f32>> = None;
        while state.n_tokens < tokens.len() {
            let pos = state.n_tokens;
            let remaining = tokens.len() - pos;
            let chunk = self.pick_chunk(remaining);
            let valid = remaining.min(chunk);
            let mut piece: Vec<i32> = Vec::with_capacity(chunk);
            piece.extend(tokens[pos..pos + valid].iter().map(|&t| t as i32));
            piece.resize(chunk, 0);

            let out = pacer.paced_prefill(valid, || {
                self.model
                    .prefill(chunk, &state.k, &state.v, &piece, pos as i32, valid as i32)
            });
            let out = out?;
            state.k = out.kcache;
            state.v = out.vcache;
            state.n_tokens = pos + valid;
            let vocab = self.model.config.vocab;
            let row = &out.logits[(valid - 1) * vocab..valid * vocab];
            last_logits = Some(row.to_vec());
            bd.prompt_tokens += valid;
        }
        Ok(last_logits)
    }

    /// Prefill the first `rows` prompt tokens from scratch into a fresh
    /// state (paced).  The local-recompute feeder of the chunk-level fetch
    /// plan (`coordinator::plan`) uses this to regenerate the cheap prefix
    /// of a matched range while the expensive suffix is still on the wire;
    /// phase attribution stays with the caller (the feeder's wall time is
    /// already inside the fetch's Redis window).
    pub fn prefill_prefix(
        &self,
        tokens: &[u32],
        rows: usize,
        pacer: &mut Pacer,
    ) -> Result<KvState> {
        let rows = rows.min(tokens.len());
        let mut state = self.fresh_state();
        let mut bd = PhaseBreakdown::default();
        self.prefill_suffix(&mut state, &tokens[..rows], pacer, &mut bd)?;
        Ok(state)
    }

    /// First-token logits for a prompt whose state is already (fully or
    /// partially) cached.  Partial → prefill the suffix (attributed to
    /// P-decode).  Full → one re-derivation decode step (attributed to
    /// R-decode, matching Table 3 where Case 5 has P-decode = 0).
    pub fn first_logits(
        &self,
        state: &mut KvState,
        tokens: &[u32],
        pacer: &mut Pacer,
        bd: &mut PhaseBreakdown,
    ) -> Result<Vec<f32>> {
        if state.n_tokens < tokens.len() {
            let t0 = std::time::Instant::now();
            let logits = self.prefill_suffix(state, tokens, pacer, bd)?;
            bd.add(Phase::PDecode, t0.elapsed());
            return Ok(logits.expect("suffix was non-empty"));
        }
        // fully cached: re-derive the last token's logits with one decode step
        let last = *tokens.last().expect("non-empty prompt") as i32;
        let pos = (tokens.len() - 1) as i32;
        let logits = bd.time(Phase::RDecode, || {
            pacer.paced_decode(1, || {
                self.model
                    .decode_in_place(&mut state.k, &mut state.v, last, pos)
            })
        })?;
        // row pos is rewritten with identical K/V; n_tokens unchanged
        Ok(logits)
    }

    /// Autoregressive generation from already-computed first-token logits.
    pub fn decode_loop(
        &self,
        state: &mut KvState,
        first_logits: Vec<f32>,
        max_new: usize,
        sampler: &mut Sampler,
        pacer: &mut Pacer,
        bd: &mut PhaseBreakdown,
    ) -> Result<Vec<u32>> {
        let mut out = Vec::with_capacity(max_new);
        let mut logits = first_logits;
        for _ in 0..max_new {
            let t = bd.time(Phase::Sample, || {
                pacer.paced_sample(1, || sampler.sample(&logits))
            });
            out.push(t);
            bd.response_tokens += 1;
            if t == self.eos_token {
                break;
            }
            if state.n_tokens >= self.model.config.max_seq {
                break; // cache full
            }
            let pos = state.n_tokens as i32;
            logits = bd.time(Phase::RDecode, || {
                pacer.paced_decode(1, || {
                    self.model
                        .decode_in_place(&mut state.k, &mut state.v, t as i32, pos)
                })
            })?;
            state.n_tokens += 1;
        }
        Ok(out)
    }

    /// Convenience: tokenize → prefill → generate, all local (no cache box).
    /// This is the paper's baseline Case-1 path.
    pub fn generate(
        &self,
        prompt: &str,
        max_new: usize,
        pacer: &mut Pacer,
    ) -> Result<GenOutput> {
        let mut bd = PhaseBreakdown::default();
        let tokens = bd.time(Phase::Token, || {
            let est = prompt.len() / 3;
            pacer.paced_tokenize(est, || self.tokenize_prompt(prompt))
        });
        let mut state = self.fresh_state();
        let t0 = std::time::Instant::now();
        let first = self.prefill_suffix(&mut state, &tokens, pacer, &mut bd)?;
        bd.add(Phase::PDecode, t0.elapsed());
        let first = first.expect("prompt non-empty");
        let mut sampler = Sampler::greedy();
        let out_tokens =
            self.decode_loop(&mut state, first, max_new, &mut sampler, pacer, &mut bd)?;
        let text = self.tokenizer.decode(&out_tokens);
        Ok(GenOutput {
            prompt_tokens: tokens.len(),
            reused_tokens: 0,
            tokens: out_tokens,
            text,
            breakdown: bd,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::devicemodel::DeviceProfile;

    fn engine() -> Option<Engine> {
        let dir = crate::artifacts_dir().join("tiny");
        if !dir.join("meta.json").exists() {
            eprintln!("skipping: artifacts/tiny missing");
            return None;
        }
        Some(Engine::load_preset("tiny").unwrap())
    }

    fn host_pacer() -> Pacer {
        Pacer::new(DeviceProfile::host())
    }

    #[test]
    fn generate_end_to_end() {
        let Some(e) = engine() else { return };
        let mut p = host_pacer();
        let out = e.generate("What is the answer? A. yes B. no Answer:", 4, &mut p).unwrap();
        assert!(out.prompt_tokens > 4);
        assert!(!out.tokens.is_empty());
        assert!(out.breakdown.get(Phase::PDecode) > std::time::Duration::ZERO);
        assert!(out.breakdown.ttft() <= out.breakdown.ttlt());
    }

    #[test]
    fn generation_deterministic() {
        let Some(e) = engine() else { return };
        let mut p = host_pacer();
        let a = e.generate("the quick brown fox", 6, &mut p).unwrap();
        let b = e.generate("the quick brown fox", 6, &mut p).unwrap();
        assert_eq!(a.tokens, b.tokens);
    }

    #[test]
    fn state_restore_reproduces_generation() {
        // The paper's core correctness claim: restoring an uploaded state
        // yields identical output to local prefill.
        let Some(e) = engine() else { return };
        let mut p = host_pacer();
        let prompt = "In astronomy, the standard model directly determines the answer?";
        let tokens = e.tokenize_prompt(prompt);

        // local path
        let mut bd1 = PhaseBreakdown::default();
        let mut s1 = e.fresh_state();
        let l1 = e.prefill_suffix(&mut s1, &tokens, &mut p, &mut bd1).unwrap().unwrap();

        // snapshot -> blob -> restore path (as if downloaded from cache box)
        let blob = s1.serialize(e.model_hash(), crate::model::state::Compression::None);
        let cfg = &e.model.config;
        let mut s2 = KvState::restore(
            &blob,
            e.model_hash(),
            (cfg.n_layers, cfg.max_seq, cfg.n_kv_heads, cfg.head_dim),
        )
        .unwrap();
        let mut bd2 = PhaseBreakdown::default();
        let l2 = e.first_logits(&mut s2, &tokens, &mut p, &mut bd2).unwrap();

        // first-token logits agree (full-hit path re-derives via decode)
        for (a, b) in l1.iter().zip(&l2) {
            assert!((a - b).abs() < 2e-3, "{a} vs {b}");
        }

        // and the whole continuation matches
        let mut sm1 = Sampler::greedy();
        let mut sm2 = Sampler::greedy();
        let g1 = e
            .decode_loop(&mut s1, l1, 5, &mut sm1, &mut p, &mut bd1)
            .unwrap();
        let g2 = e
            .decode_loop(&mut s2, l2, 5, &mut sm2, &mut p, &mut bd2)
            .unwrap();
        assert_eq!(g1, g2);
    }

    #[test]
    fn partial_prefix_reuse_matches_full_prefill() {
        let Some(e) = engine() else { return };
        let mut p = host_pacer();
        let full_text = "The following are questions about physics. What is mass? Answer:";
        let tokens = e.tokenize_prompt(full_text);
        let cut = tokens.len() / 2;

        // path A: full local prefill
        let mut bd = PhaseBreakdown::default();
        let mut sa = e.fresh_state();
        let la = e.prefill_suffix(&mut sa, &tokens, &mut p, &mut bd).unwrap().unwrap();

        // path B: prefill prefix only, snapshot, restore, prefill suffix
        let mut sb = e.fresh_state();
        e.prefill_suffix(&mut sb, &tokens[..cut], &mut p, &mut bd).unwrap();
        let blob = sb.serialize(e.model_hash(), crate::model::state::Compression::None);
        let cfg = &e.model.config;
        let mut sb2 = KvState::restore(
            &blob,
            e.model_hash(),
            (cfg.n_layers, cfg.max_seq, cfg.n_kv_heads, cfg.head_dim),
        )
        .unwrap();
        assert_eq!(sb2.n_tokens, cut);
        let lb = e
            .prefill_suffix(&mut sb2, &tokens, &mut p, &mut bd)
            .unwrap()
            .unwrap();

        for (a, b) in la.iter().zip(&lb) {
            assert!((a - b).abs() < 2e-3, "{a} vs {b}");
        }
    }

    #[test]
    fn chunk_selection() {
        let Some(e) = engine() else { return };
        // tiny has chunks [8, 16, 64]
        assert_eq!(e.pick_chunk(3), 8);
        assert_eq!(e.pick_chunk(8), 8);
        assert_eq!(e.pick_chunk(12), 16);
        assert_eq!(e.pick_chunk(16), 16);
        assert_eq!(e.pick_chunk(40), 64);
        assert_eq!(e.pick_chunk(200), 64, "larger than max -> loop with max");
    }

    #[test]
    fn eos_stops_generation() {
        let Some(e) = engine() else { return };
        let mut p = host_pacer();
        let tokens = e.tokenize_prompt("hello world");
        let mut s = e.fresh_state();
        let mut bd = PhaseBreakdown::default();
        let logits = e
            .prefill_suffix(&mut s, &tokens, &mut p, &mut bd)
            .unwrap()
            .unwrap();
        // force EOS to be the argmax by rigging logits
        let mut rigged = vec![0.0f32; logits.len()];
        rigged[crate::tokenizer::EOS as usize] = 100.0;
        let mut sm = Sampler::greedy();
        let out = e
            .decode_loop(&mut s, rigged, 10, &mut sm, &mut p, &mut bd)
            .unwrap();
        assert_eq!(out, vec![crate::tokenizer::EOS]);
    }

    #[test]
    fn pacing_stretches_generate() {
        let Some(e) = engine() else { return };
        // a profile with tiny-but-nonzero rates keeps the test fast
        let prof = DeviceProfile {
            name: "test-slow",
            prefill_ms_per_tok: 5.0,
            decode_ms_per_tok: 5.0,
            sample_ms_per_tok: 0.0,
            tokenize_ms_per_tok: 0.0,
            bloom_ms_per_lookup: 0.0,
            typical_response_tokens: 2,
        };
        let mut p = Pacer::new(prof);
        let t0 = std::time::Instant::now();
        let out = e.generate("short prompt", 2, &mut p).unwrap();
        let target = 5 * out.prompt_tokens as u64;
        assert!(
            t0.elapsed().as_millis() as u64 >= target,
            "paced run must take ≥ {target} ms"
        );
        assert!(p.injected > std::time::Duration::ZERO);
    }
}
