//! Fetch-plan ablation: the per-chunk planner (`coordinator::plan`) vs the
//! two extremes (all-fetch / all-recompute) and the PR 3 whole-range
//! break-even policy, swept over the device × link × state-scale ×
//! prefix-length grid.
//!
//! The sweep is analytic — it exercises the exact cost model the fabric
//! plans with, so it maps *where* mixed plans pay off: on a slow link with
//! a fast device the optimum splits the range (cheap prefix recomputed
//! while the tail streams), and neither extreme nor the binary policy can
//! reach it.  Asserted:
//!
//! * every cell: the planned cost is ≤ both extremes (the planner
//!   dominates by construction) and never loses to the binary policy by
//!   more than 5 %;
//! * at least one slow-link/fast-device cell where the mixed plan
//!   *strictly* beats both extremes;
//! * `plan_split` matches the exhaustive 2^k argmin on every cell small
//!   enough to enumerate.
//!
//! Emits `BENCH_plan.json`.
//!
//! Env: EDGECACHE_SMOKE=1 (reduced grid for the check.sh gate),
//!      EDGECACHE_PLAN_JSON (output path, default BENCH_plan.json).

use edgecache::coordinator::plan::{
    cost_of, plan_exhaustive, plan_split, ChunkCost, ChunkSource, LinkCost,
    EXHAUSTIVE_MAX_CHUNKS,
};
use edgecache::coordinator::FetchPolicy;
use edgecache::devicemodel::DeviceProfile;
use edgecache::netsim::LinkModel;
use edgecache::report::ascii_table;
use edgecache::util::json::Json;

const EPS: f64 = 1e-9;

fn main() {
    edgecache::util::logger::init_from_env();
    let smoke = std::env::var("EDGECACHE_SMOKE").is_ok();

    let devices = [
        ("pi-zero-2w", DeviceProfile::pi_zero_2w()),
        ("pi5-4gb", DeviceProfile::pi5_4gb()),
    ];
    let links = [
        ("wifi4-2g4", LinkModel::wifi4_2g4()),
        ("ethernet-1g", LinkModel::ethernet_1g()),
    ];
    // (label, uncompressed state bytes/token, wire compression ratio)
    let scales = [
        ("270M raw", 34_474usize, 1.0f64),
        ("270M deflate", 34_474, 0.6),
        ("1B raw", 29_751, 1.0),
    ];
    let prefixes: &[usize] = if smoke { &[128] } else { &[64, 128, 256, 512] };
    let ct = 16usize; // tokens per ECS3 chunk

    println!("== per-chunk fetch planning vs extremes vs whole-range break-even ==\n");
    let mut rows = Vec::new();
    let mut cells = Vec::new();
    let mut mixed_strict_win = false;

    for (dname, dev) in &devices {
        for (lname, link) in &links {
            for (sname, bpt, ratio) in &scales {
                for &m in prefixes {
                    let k = m.div_ceil(ct);
                    let chunk_wire = (*bpt as f64 * ct as f64 * ratio) as usize;
                    let chunks: Vec<ChunkCost> = (0..k)
                        .map(|_| ChunkCost { wire_bytes: chunk_wire, tokens: ct })
                        .collect();
                    let lcosts = [LinkCost::from_link(link)];
                    let rate = dev.prefill_ms_per_tok;

                    let plan = plan_split(&chunks, &lcosts, rate);
                    let fetch =
                        cost_of(&chunks, &lcosts, rate, &vec![ChunkSource::Fetch; k]).total_s;
                    let recompute =
                        cost_of(&chunks, &lcosts, rate, &vec![ChunkSource::Recompute; k])
                            .total_s;
                    // the PR 3 ablation: one break-even decision for the
                    // whole range, then all-fetch or all-recompute
                    let binary = if FetchPolicy::BreakEven.should_fetch(
                        dev,
                        link,
                        m,
                        (m as f64 * *bpt as f64 * ratio) as usize,
                    ) {
                        fetch
                    } else {
                        recompute
                    };

                    let planned = plan.cost.total_s;
                    assert!(
                        planned <= fetch + EPS && planned <= recompute + EPS,
                        "{dname}/{lname}/{sname}/m={m}: plan {planned:.4}s worse than an \
                         extreme (fetch {fetch:.4}s, recompute {recompute:.4}s)"
                    );
                    assert!(
                        planned <= binary * 1.05 + EPS,
                        "{dname}/{lname}/{sname}/m={m}: plan {planned:.4}s loses >5% to \
                         the binary policy ({binary:.4}s)"
                    );
                    if k <= EXHAUSTIVE_MAX_CHUNKS {
                        let oracle = plan_exhaustive(&chunks, &lcosts, rate);
                        assert!(
                            (planned - oracle.cost.total_s).abs() <= EPS,
                            "{dname}/{lname}/{sname}/m={m}: split plan {planned:.6}s != \
                             exhaustive optimum {:.6}s",
                            oracle.cost.total_s
                        );
                    }
                    let strict =
                        planned < fetch * 0.99 - EPS && planned < recompute * 0.99 - EPS;
                    if strict && *dname == "pi5-4gb" && *lname == "wifi4-2g4" {
                        mixed_strict_win = true;
                    }

                    rows.push(vec![
                        dname.to_string(),
                        lname.to_string(),
                        sname.to_string(),
                        m.to_string(),
                        format!("{fetch:.3}"),
                        format!("{recompute:.3}"),
                        format!("{binary:.3}"),
                        format!("{planned:.3}"),
                        format!("{}/{}", plan.fetched(), plan.recomputed()),
                        if strict { "mixed-win" } else { "" }.to_string(),
                    ]);
                    cells.push(Json::obj(vec![
                        ("device", Json::str(*dname)),
                        ("link", Json::str(*lname)),
                        ("scale", Json::str(*sname)),
                        ("prefix_tokens", Json::Int(m as i64)),
                        ("chunks", Json::Int(k as i64)),
                        ("all_fetch_s", Json::Num(fetch)),
                        ("all_recompute_s", Json::Num(recompute)),
                        ("binary_s", Json::Num(binary)),
                        ("planned_s", Json::Num(planned)),
                        ("fetched", Json::Int(plan.fetched() as i64)),
                        ("recomputed", Json::Int(plan.recomputed() as i64)),
                        ("mixed", Json::Bool(plan.is_mixed())),
                    ]));
                }
            }
        }
    }

    println!(
        "{}",
        ascii_table(
            &[
                "device", "link", "scale", "m", "fetch [s]", "recompute [s]",
                "binary [s]", "planned [s]", "F/R", "",
            ],
            &rows
        )
    );
    assert!(
        mixed_strict_win,
        "expected at least one pi5/wifi cell where the mixed plan strictly \
         beats both extremes"
    );
    println!(
        "mixed plans strictly beat both extremes on the slow-link/fast-device \
         cells and never lose to the PR 3 binary policy."
    );

    let json = Json::obj(vec![
        ("bench", Json::str("fetch_plan")),
        ("smoke", Json::Bool(smoke)),
        ("chunk_tokens", Json::Int(ct as i64)),
        ("cells", Json::Arr(cells)),
    ]);
    let path = std::env::var("EDGECACHE_PLAN_JSON")
        .unwrap_or_else(|_| "BENCH_plan.json".into());
    match std::fs::write(&path, json.to_pretty()) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => println!("\ncould not write {path}: {e}"),
    }
    println!("fetch_plan done.");
}
