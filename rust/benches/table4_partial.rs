//! Regenerates **Table 4 + Figure 5**: total decoding time (T-decode =
//! P-decode + R-decode) under the five partial-matching cases, for one
//! astronomy N=5 prompt, on both settings; Figure 5 stacks the Redis
//! download cost on top for the low-end setting.
//!
//! The real track replays the actual five cases through the stack (tiny
//! preset): seed upload, then queries crafted to land in Cases 1–5.
//!
//! Env: EDGECACHE_REAL (default on), EDGECACHE_SHOTS (2 for tiny).

use std::sync::Arc;

use edgecache::coordinator::{CacheBox, EdgeClient, EdgeClientConfig};
use edgecache::engine::Engine;
use edgecache::report::experiments as exp;
use edgecache::report::{ascii_stacked_bars, ascii_table};
use edgecache::workload::{Generator, Prompt};

fn main() {
    edgecache::util::logger::init_from_env();
    let seed = 42;

    println!("================================================================");
    println!(" Table 4 + Figure 5 — partial matching (astronomy, N=5)");
    println!("================================================================");

    println!("\n--- analytic track ---\n");
    for s in [exp::Setting::low_end_paper(), exp::Setting::high_end_paper()] {
        let rows = exp::analytic_table4(&s, seed);
        let body: Vec<Vec<String>> = rows
            .iter()
            .map(|(c, m, pct, td, _)| {
                vec![
                    format!("{} (Case {c})", s.name),
                    m.to_string(),
                    format!("{pct:.2}"),
                    format!("{:.2}", td * 1e3),
                ]
            })
            .collect();
        println!(
            "{}",
            ascii_table(
                &["Setting", "# matched", "% matched", "T-decode [ms]"],
                &body
            )
        );
        if s.name == "Low-end" {
            let bars: Vec<(String, f64, f64)> = rows
                .iter()
                .map(|(c, _, _, td, redis)| (format!("Case {c}"), *td, *redis))
                .collect();
            println!(
                "{}",
                ascii_stacked_bars(
                    "Figure 5 — Low-end: T-decode + Redis overhead [s]",
                    &bars,
                    "T-decode",
                    "Redis",
                    "s"
                )
            );
        }
    }
    println!("paper reference (low-end, 405-token prompt):");
    println!("  matched 1/10/57/340/405 -> T-decode 27204/26288/24590/13345/11221 ms");
    println!("  (shape: monotone decrease; the knee is at Case 4)");

    if std::env::var("EDGECACHE_REAL").as_deref() == Ok("0") {
        return;
    }
    println!("\n--- real track (tiny preset, native) ---\n");
    let engine = match Engine::load_preset("tiny") {
        Ok(e) => Arc::new(e),
        Err(e) => {
            println!("skipping real track: {e}");
            return;
        }
    };
    let shots: usize = std::env::var("EDGECACHE_SHOTS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(2);
    let cb = CacheBox::start_local().expect("cache box");
    let mut cfg = EdgeClientConfig::native(Some(cb.addr()));
    cfg.max_new_tokens = Some(2);
    cfg.sync_interval = None;
    // EDGECACHE_COMPRESS=1 runs the real track over chunk-compressed (ECS3
    // deflate) entries — partial matches still ride the range path
    let compress = std::env::var("EDGECACHE_COMPRESS").as_deref() == Ok("1");
    if compress {
        cfg.compression = edgecache::model::state::Compression::Deflate;
        println!("(compression: ECS3 deflate, chunk_tokens={})\n", cfg.chunk_tokens);
    }
    let mut client = EdgeClient::new(Arc::clone(&engine), cfg).expect("client");

    let gen = Generator::new(seed);
    let seed_prompt = gen.prompt("astronomy", 0, shots);
    let case2 = Prompt {
        examples: gen.prompt("astronomy", 0, 0).examples.clone(),
        target: gen.prompt("virology", 7, 0).target.clone(),
        ..seed_prompt.clone()
    };
    let case3 = Prompt {
        examples: {
            let mut e = seed_prompt.examples.clone();
            for x in e.iter_mut().skip(1) {
                *x = seed_prompt.examples[0].replace("Answer", "ANSWER");
            }
            e
        },
        ..seed_prompt.clone()
    };
    let case4 = gen.prompt("astronomy", 1, shots);
    let case5 = seed_prompt.clone();
    let case1 = gen.prompt("world_religions", 3, shots);

    let r0 = client.query(&seed_prompt).expect("seed");
    println!(
        "seeded cache: uploaded {:.2} MB across the prompt's ranges\n",
        r0.uploaded_bytes as f64 / 1e6
    );
    let mut body = Vec::new();
    for (label, p) in [
        ("Case 1", &case1),
        ("Case 2", &case2),
        ("Case 3", &case3),
        ("Case 4", &case4),
        ("Case 5", &case5),
    ] {
        let r = client.query(p).expect(label);
        body.push(vec![
            format!("{label} (landed {})", r.case.number()),
            r.matched_tokens.to_string(),
            format!(
                "{:.2}",
                r.matched_tokens as f64 / r.prompt_tokens as f64 * 100.0
            ),
            format!("{:.2}", r.breakdown.t_decode().as_secs_f64() * 1e3),
            format!(
                "{:.2}",
                r.breakdown
                    .get(edgecache::metrics::Phase::Redis)
                    .as_secs_f64()
                    * 1e3
            ),
        ]);
    }
    println!(
        "{}",
        ascii_table(
            &["Query", "# matched", "% matched", "T-decode [ms]", "Redis [ms]"],
            &body
        )
    );
    println!(
        "wire ledger: {:.2} MB moved ({:.2} MB logical), {} range fetches, {} full-blob fallbacks, {:.2} MB saved vs per-range blobs, {:.2} ms decode/wire overlap credited",
        client.link_moved_bytes() as f64 / 1e6,
        client.link_inflated_bytes() as f64 / 1e6,
        client.stats.range_fetches,
        client.stats.full_fetch_fallbacks,
        client.stats.bytes_saved as f64 / 1e6,
        client.link_overlap_saved().as_secs_f64() * 1e3
    );
    client.shutdown();
    cb.shutdown();
}
