//! Placement acceptance bench — ring vs p2c on the three axes the
//! placement API redesign trades between, measured over real cache-box
//! TCP servers where sockets matter:
//!
//! * **(a) load balance** (no sockets): place many synthetic keys with
//!   both policies and compare byte-load imbalance (max/mean).  p2c
//!   probes loads and balances almost perfectly; the ring trades a
//!   bounded hash imbalance — asserted under [`RING_BALANCE_BOUND`], the
//!   bound README documents — for determinism.
//! * **(b) post-reboot hit rate**: entries are uploaded through each
//!   policy, then the client "reboots" with empty Bloom state and no
//!   sync.  The ring recovers by probing each key's 1+k designated
//!   owners; p2c has no owner set to probe and recovers nothing.
//!   Asserted: ring hit rate strictly beats p2c's.
//! * **(c) post-death re-replication**: ring-placed replicated entries
//!   lose a box mid-fleet; `fabric::repair_entry` sweeps the recomputed
//!   owner sets and re-publishes the missing copies.  Asserted: every
//!   surviving entry is back at the configured replication factor.
//!
//! Emits `BENCH_placement.json`.
//!
//! Env: EDGECACHE_SMOKE=1 (reduced sizes for the check.sh gate),
//!      EDGECACHE_PLACEMENT_JSON (output path, default
//!      BENCH_placement.json).

use edgecache::coordinator::fabric::{repair_entry, Peer, PeerConfig};
use edgecache::coordinator::placement::{
    Placement, PowerOfTwoChoices, RendezvousRing,
};
use edgecache::coordinator::{CacheBox, PeerPlanner};
use edgecache::kvstore::KvClient;
use edgecache::netsim::LinkModel;
use edgecache::util::bytes::SharedBytes;
use edgecache::util::json::Json;
use edgecache::util::rng::Rng;

/// Documented balance bound (see README "Placement"): ring byte-load
/// imbalance (max peer load / mean peer load) stays under this at ≥256
/// uniform keys over 4 peers.
const RING_BALANCE_BOUND: f64 = 1.35;

fn synth_keys(n: usize, seed: u64) -> Vec<Vec<u8>> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| (0..16).map(|_| rng.below(256) as u8).collect())
        .collect()
}

fn main() {
    edgecache::util::logger::init_from_env();
    let smoke = std::env::var("EDGECACHE_SMOKE").as_deref() == Ok("1");

    println!("=================================================================");
    println!(" placement — ring vs p2c: balance, reboot recovery, repair{}",
        if smoke { "  [smoke]" } else { "" });
    println!("=================================================================");

    // ---- (a) byte-load balance across 4 synthetic peers -----------------
    let n_keys = if smoke { 256 } else { 1024 };
    let keys = synth_keys(n_keys, 5);
    let mut rng = Rng::new(6);
    let sizes: Vec<u64> = (0..n_keys).map(|_| 8_000 + rng.below(24_000)).collect();

    let ring = RendezvousRing::new((0..4).map(|i| format!("box-{i}:760{i}")).collect());
    let mut ring_load = [0u64; 4];
    for (k, &sz) in keys.iter().zip(&sizes) {
        ring_load[ring.owners(k, 0)[0]] += sz;
    }

    let mut p2c = PowerOfTwoChoices::new(4, PeerPlanner::default(), 7);
    let mut p2c_load = [0u64; 4];
    for (k, &sz) in keys.iter().zip(&sizes) {
        let loads = p2c_load;
        let target = p2c.place_upload(k, 0, &mut |i| loads[i])[0];
        p2c_load[target] += sz;
    }

    let imbalance = |loads: &[u64; 4]| -> f64 {
        let total: u64 = loads.iter().sum();
        let mean = total as f64 / 4.0;
        *loads.iter().max().unwrap() as f64 / mean
    };
    let (ring_imb, p2c_imb) = (imbalance(&ring_load), imbalance(&p2c_load));
    println!(
        "(a) {n_keys} keys over 4 peers: byte imbalance ring {ring_imb:.3}x mean, p2c {p2c_imb:.3}x mean \
         (documented ring bound {RING_BALANCE_BOUND}x)"
    );
    assert!(
        ring_imb <= RING_BALANCE_BOUND,
        "ring byte-load imbalance {ring_imb:.3} exceeds the documented bound {RING_BALANCE_BOUND}"
    );

    // ---- (b) post-reboot hit rate: owner probing vs nothing -------------
    // Option-wrapped so the (c) section can kill one box by value while
    // the others stay indexable
    let mut boxes: Vec<Option<CacheBox>> = (0..3)
        .map(|_| Some(CacheBox::start_local().expect("cache box")))
        .collect();
    let addrs: Vec<String> = boxes
        .iter()
        .map(|b| b.as_ref().unwrap().addr())
        .collect();
    let mut conns: Vec<KvClient> = addrs
        .iter()
        .map(|a| KvClient::connect(a).expect("conn"))
        .collect();
    let n_entries = if smoke { 8 } else { 24 };
    let replicas = 1usize;
    let mut payload_rng = Rng::new(9);
    let payload = |rng: &mut Rng| -> Vec<u8> {
        let len = 4_000 + rng.below(12_000) as usize;
        (0..len).map(|_| rng.below(256) as u8).collect()
    };

    let mut policies: Vec<(&str, Box<dyn Placement>)> = vec![
        (
            "ring",
            Box::new(RendezvousRing::new(addrs.clone())),
        ),
        (
            "p2c",
            Box::new(PowerOfTwoChoices::new(addrs.len(), PeerPlanner::default(), 11)),
        ),
    ];
    let mut hit_rates: Vec<(String, f64)> = Vec::new();
    for (pname, policy) in policies.iter_mut() {
        // a warm fleet: every entry uploaded to primary + replica
        let entry_keys: Vec<Vec<u8>> = (0..n_entries)
            .map(|e| format!("state:{pname}:{e}").into_bytes())
            .collect();
        for key in &entry_keys {
            let targets = policy.place_upload(key, replicas, &mut |i| {
                conns[i].used_bytes().map(|v| v as u64).unwrap_or(u64::MAX)
            });
            assert!(!targets.is_empty(), "{pname}: placement must name a target");
            let blob = payload(&mut payload_rng);
            for &t in &targets {
                conns[t].set(key, &blob).expect("seed upload");
            }
        }
        // "reboot": empty Bloom state, sync lagging — the only recourse is
        // deterministic owner probing, bounded to primary + replicas
        let mut hits = 0usize;
        let mut probes = 0usize;
        for key in &entry_keys {
            let owners = policy.owners(key, replicas);
            probes += owners.len();
            if owners
                .iter()
                .any(|&i| conns[i].exists(key).unwrap_or(false))
            {
                hits += 1;
            }
        }
        let rate = hits as f64 / n_entries as f64;
        println!(
            "(b) {pname}: post-reboot hit rate {rate:.2} ({hits}/{n_entries}, {probes} bounded probes)"
        );
        hit_rates.push((pname.to_string(), rate));
    }
    let ring_rate = hit_rates.iter().find(|(n, _)| n == "ring").unwrap().1;
    let p2c_rate = hit_rates.iter().find(|(n, _)| n == "p2c").unwrap().1;
    assert!(
        ring_rate > p2c_rate,
        "ring post-reboot hit rate ({ring_rate}) must strictly beat p2c's ({p2c_rate})"
    );
    assert_eq!(ring_rate, 1.0, "every ring-placed entry must be recoverable");

    // ---- (c) post-death re-replication via fabric::repair_entry ---------
    let mut ring = RendezvousRing::new(addrs.clone());
    let repair_keys: Vec<Vec<u8>> = (0..n_entries)
        .map(|e| format!("state:repair:{e}").into_bytes())
        .collect();
    for key in &repair_keys {
        let blob = payload(&mut payload_rng);
        for &o in &ring.owners(key, replicas) {
            conns[o].set(key, &blob).expect("seed replicated entry");
        }
    }
    let mut peers: Vec<Peer> = addrs
        .iter()
        .enumerate()
        .map(|(i, a)| {
            Peer::connect(PeerConfig::new(a.clone()), LinkModel::loopback(), 20 + i as u64, 1)
                .expect("peer connect")
        })
        .collect();
    // kill the primary owner of the first entry — it certainly holds data
    let dead = ring.owners(&repair_keys[0], replicas)[0];
    let owned_by_dead = repair_keys
        .iter()
        .filter(|k| ring.owners(k, replicas).contains(&dead))
        .count();
    println!("(c) killing box {dead} ({owned_by_dead}/{n_entries} entries lose a copy)");
    boxes[dead].take().expect("box alive").shutdown();
    let mut alive = vec![true; addrs.len()];
    alive[dead] = false;
    ring.on_membership_change(&alive);

    // the repair sweep any client runs after using an entry: recompute the
    // owner set, probe it, re-publish where the copy is gone
    let mut republished = 0u64;
    for key in &repair_keys {
        let owners = ring.owners(key, replicas);
        assert!(!owners.contains(&dead), "dead boxes never own");
        let src = owners
            .iter()
            .copied()
            .find(|&i| conns[i].exists(key).unwrap_or(false))
            .expect("a surviving owner still serves the entry");
        let blob: SharedBytes = conns[src].get(key).expect("fetch").expect("entry bytes");
        let out = repair_entry(&mut peers, &owners, key, None, &mut || blob.clone());
        republished += out.republished;
        assert_eq!(out.dead, 0, "repair must only touch live owners");
    }
    assert_eq!(
        republished as usize, owned_by_dead,
        "exactly the entries that lost a copy get re-published"
    );
    assert!(republished >= 1, "the dead box must have owned something");
    // replication factor restored: every entry serves from its full
    // (recomputed) owner set
    for key in &repair_keys {
        for &o in &ring.owners(key, replicas) {
            assert!(
                conns[o].exists(key).unwrap_or(false),
                "entry {:?} missing on owner {o} after repair",
                String::from_utf8_lossy(key)
            );
        }
    }
    println!(
        "(c) repair re-published {republished} copies; replication factor {} restored for all {n_entries} entries",
        1 + replicas
    );

    let json = Json::obj(vec![
        ("smoke", Json::Bool(smoke)),
        (
            "balance",
            Json::obj(vec![
                ("keys", Json::Int(n_keys as i64)),
                ("peers", Json::Int(4)),
                ("ring_imbalance_x", Json::Num(ring_imb)),
                ("p2c_imbalance_x", Json::Num(p2c_imb)),
                ("ring_bound_x", Json::Num(RING_BALANCE_BOUND)),
            ]),
        ),
        (
            "post_reboot",
            Json::obj(vec![
                ("entries", Json::Int(n_entries as i64)),
                ("replicas", Json::Int(replicas as i64)),
                ("ring_hit_rate", Json::Num(ring_rate)),
                ("p2c_hit_rate", Json::Num(p2c_rate)),
            ]),
        ),
        (
            "repair",
            Json::obj(vec![
                ("entries", Json::Int(n_entries as i64)),
                ("lost_copies", Json::Int(owned_by_dead as i64)),
                ("republished", Json::Int(republished as i64)),
                ("replication_factor", Json::Int((1 + replicas) as i64)),
            ]),
        ),
    ]);
    let path = std::env::var("EDGECACHE_PLACEMENT_JSON")
        .unwrap_or_else(|_| "BENCH_placement.json".into());
    match std::fs::write(&path, json.to_pretty()) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => println!("\ncould not write {path}: {e}"),
    }
    for cb in boxes.into_iter().flatten() {
        cb.shutdown();
    }
    println!("placement done.");
}
