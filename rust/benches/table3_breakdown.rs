//! Regenerates **Table 3**: the six-component latency breakdown (Token /
//! Bloom / P-decode / Redis / R-decode / Sample) for both settings under
//! Case 1 and Case 5, plus # tokens and state size.
//!
//! Analytic track at population scale; real track shows the same breakdown
//! measured through the actual client flow on the tiny preset.
//!
//! Env: EDGECACHE_BENCH_PROMPTS (default 6434), EDGECACHE_REAL_PROMPTS (4).

use std::sync::Arc;

use edgecache::engine::Engine;
use edgecache::metrics::Phase;
use edgecache::report::experiments as exp;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() {
    edgecache::util::logger::init_from_env();
    let n = env_usize("EDGECACHE_BENCH_PROMPTS", 6434);
    let n_real = env_usize("EDGECACHE_REAL_PROMPTS", 4);
    let seed = 42;

    println!("================================================================");
    println!(" Table 3 — latency breakdown [ms] per setting and case");
    println!("================================================================");

    println!("\n--- analytic track ({n} prompts/setting) ---\n");
    let lo = exp::Setting::low_end_paper();
    let hi = exp::Setting::high_end_paper();
    let (lo_miss, lo_hit) = exp::analytic_table23(&lo, seed, n);
    let (hi_miss, hi_hit) = exp::analytic_table23(&hi, seed, n);
    println!(
        "{}",
        exp::render_table3(&[
            ("Low-end (Case 1)", &lo_miss, lo.n_shots, lo.max_new),
            ("Low-end (Case 5)", &lo_hit, lo.n_shots, lo.max_new),
            ("High-end (Case 1)", &hi_miss, hi.n_shots, hi.max_new),
            ("High-end (Case 5)", &hi_hit, hi.n_shots, hi.max_new),
        ])
    );
    println!("paper reference [ms]:");
    println!("  Low-end  (1): Token 3.46  Bloom 0.30 P-dec 12580.85 Redis 2.42†  R-dec 11061.04 Sample 95.69");
    println!("  Low-end  (5): Token 3.46  Bloom 0.19 P-dec 0.00     Redis 861.92 R-dec 10904.67 Sample 84.82");
    println!("  High-end (1): Token 1.61  Bloom 0.00 P-dec 2688.17  Redis 7.84†  R-dec 72.59    Sample 1.45");
    println!("  High-end (5): Token 1.56  Bloom 0.00 P-dec 0.00     Redis 2887.04 R-dec 78.12   Sample 1.67");
    println!("  († = expected false-positive cost)");
    println!(
        "\nshape checks: P-decode dominates Case 1 on the low-end ({}x Redis-on-hit); \
         Redis-on-hit exceeds P-decode on the high-end ({:.2}x)",
        (lo_miss.phase_mean_ms(Phase::PDecode) / lo_hit.phase_mean_ms(Phase::Redis)).round(),
        hi_hit.phase_mean_ms(Phase::Redis) / hi_miss.phase_mean_ms(Phase::PDecode)
    );

    println!("\n--- real track (tiny preset, native, {n_real} prompts) ---\n");
    match Engine::load_preset("tiny") {
        Ok(engine) => {
            let cfg = exp::RealRunCfg::native_tiny(n_real);
            match exp::real_table23(Arc::new(engine), &cfg) {
                Ok((miss, hit)) => {
                    println!(
                        "{}",
                        exp::render_table3(&[
                            ("tiny/native (Case 1)", &miss, 1, 8),
                            ("tiny/native (Case 5)", &hit, 1, 8),
                        ])
                    );
                    println!(
                        "real-stack composition: Case 5 P-decode = {:.2} ms (must be 0), \
                         Case 1 Redis = {:.2} ms (must be ~0: uploads are post-response)",
                        hit.phase_mean_ms(Phase::PDecode),
                        miss.phase_mean_ms(Phase::Redis),
                    );
                }
                Err(e) => println!("real track failed: {e}"),
            }
        }
        Err(e) => println!("skipping real track: {e}"),
    }
}
