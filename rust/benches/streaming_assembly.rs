//! Streaming state assembly — the PR-3 hot-path claim, measured.
//!
//! Compares two ways of turning a partial-match range download into a live
//! KV state, over a real cache box with the modelled link in between:
//!
//! * **store-and-forward** (the old pipeline): buffer the head and the whole
//!   matched-chunk span, then verify + inflate + scatter everything —
//!   restore cost = `transfer + decode`, paid serially;
//! * **streaming** (`StateAssembler` + `Shaper::shaped_stream` + one
//!   `GETRANGE` per chunk): decode chunk `i` while chunk `i+1` is still on
//!   the modelled wire — restore cost ≈ `max(transfer, decode)`.
//!
//! Sweeps chunk sizes × link rates, asserts the two acceptance properties
//! (streaming strictly beats store-and-forward at every measured chunk
//! size; streaming restore-complete lands within ~1 chunk-decode of
//! last-byte arrival) and emits `BENCH_streaming.json`.
//!
//! Env: EDGECACHE_SMOKE=1 (reduced sweep for the check.sh gate),
//!      EDGECACHE_STREAMING_JSON (output path, default BENCH_streaming.json).

use std::time::{Duration, Instant};

use edgecache::coordinator::CacheBox;
use edgecache::kvstore::client::getrange_req;
use edgecache::kvstore::{KvClient, Value};
use edgecache::model::state::{BlobLayout, Compression, KvState, StateAssembler};
use edgecache::netsim::{LinkModel, Shaper};
use edgecache::util::json::Json;
use edgecache::util::rng::Rng;

const HASH: &str = "bench-model";
const DIMS: (usize, usize, usize, usize) = (8, 256, 2, 64); // 16 KB/token

fn filled_state(total_rows: usize) -> KvState {
    let (l, s, kh, d) = DIMS;
    let mut st = KvState::zeroed(l, s, kh, d);
    st.n_tokens = total_rows;
    let mut rng = Rng::new(7);
    // semi-structured rows: deflate really compresses (and really inflates)
    for (i, x) in st.k.iter_mut().enumerate() {
        *x = ((i % 23) as f32) * 0.5 + if rng.f64() < 0.1 { rng.f64() as f32 } else { 0.0 };
    }
    for (i, x) in st.v.iter_mut().enumerate() {
        *x = ((i % 17) as f32) * 0.25;
    }
    st
}

struct Sample {
    store_forward: Duration,
    streaming: Duration,
    last_byte: Duration,
    tail_decode: Duration,
    overlap_saved: Duration,
    wire_bytes: usize,
}

/// One store-and-forward fetch+restore: head, then the whole matched span in
/// a single reply, then a monolithic verify+inflate+scatter.
fn run_store_forward(
    conn: &mut KvClient,
    link: &LinkModel,
    key: &[u8],
    lo: &BlobLayout,
    total: usize,
    m: usize,
) -> (Duration, usize) {
    let mut shaper = Shaper::new(link.clone(), 11);
    let head_len = lo.payload_off(total);
    let t0 = Instant::now();
    let head = shaper
        .shaped_post(|| {
            let r = conn.getrange(key, 0, head_len).unwrap().unwrap();
            let n = r.len();
            (r, n)
        });
    let asm = StateAssembler::new(&head, m, HASH, DIMS).expect("head");
    let span = asm.prefix_span();
    let rows = shaper
        .shaped_post(|| {
            let r = conn.getrange(key, head_len, span).unwrap().unwrap();
            let n = r.len();
            (r, n)
        });
    let st = KvState::restore_prefix_from_parts(&head, &rows, m, HASH, DIMS).expect("restore");
    assert_eq!(st.n_tokens, m);
    (t0.elapsed(), head.len() + rows.len())
}

/// One streaming fetch+restore: head, then one GETRANGE per chunk consumed
/// as a shaped reply stream feeding the assembler.  Returns (total,
/// last-byte arrival, overlap credited, wire bytes).
fn run_streaming(
    conn: &mut KvClient,
    link: &LinkModel,
    key: &[u8],
    lo: &BlobLayout,
    total: usize,
    m: usize,
) -> (Duration, Duration, Duration, usize) {
    let mut shaper = Shaper::new(link.clone(), 11);
    let head_len = lo.payload_off(total);
    let t0 = Instant::now();
    let head = shaper
        .shaped_post(|| {
            let r = conn.getrange(key, 0, head_len).unwrap().unwrap();
            let n = r.len();
            (r, n)
        });
    let mut asm = StateAssembler::new(&head, m, HASH, DIMS).expect("head");
    let k = asm.expected_chunks();
    let mut reqs = Vec::with_capacity(k);
    let mut off = head_len;
    for c in 0..k {
        reqs.push(getrange_req(key, off, asm.chunk_len(c)));
        off += asm.chunk_len(c);
    }
    let mut replies = conn.send_reqs(&reqs).expect("batch");
    let mut sess = shaper.shaped_stream();
    let mut last_byte = t0.elapsed();
    for _ in 0..k {
        let Some(Value::Bulk(bytes)) = replies.next_reply().expect("reply") else {
            panic!("chunk reply missing");
        };
        sess.arrived(bytes.len());
        last_byte = t0.elapsed();
        asm.feed_chunk(&bytes).expect("chunk");
    }
    let wire = head.len() + sess.bytes();
    let overlap = sess.finish();
    let st = asm.finish().expect("complete");
    assert_eq!(st.n_tokens, m);
    (t0.elapsed(), last_byte, overlap, wire)
}

/// Unshaped, network-free mean decode cost of one chunk (crc + inflate +
/// scatter) — the yardstick for the "within ~1 chunk-decode of last byte"
/// acceptance bound.
fn mean_chunk_decode(blob: &[u8], lo: &BlobLayout, total: usize, m: usize) -> Duration {
    let head = &blob[..lo.payload_off(total)];
    let mut asm = StateAssembler::new(head, m, HASH, DIMS).expect("head");
    let k = asm.expected_chunks();
    let t0 = Instant::now();
    let mut off = lo.payload_off(total);
    for c in 0..k {
        let clen = asm.chunk_len(c);
        asm.feed_chunk(&blob[off..off + clen]).expect("chunk");
        off += clen;
    }
    asm.finish().expect("complete");
    t0.elapsed() / k as u32
}

fn main() {
    edgecache::util::logger::init_from_env();
    let smoke = std::env::var("EDGECACHE_SMOKE").as_deref() == Ok("1");
    let (l, _, kh, d) = DIMS;
    let total = 192usize;
    let m = 144usize;
    // the smoke run gates check.sh: take enough samples that one scheduler
    // preemption cannot fail the assertions below (they compare per-metric
    // minima across iterations, the noise-robust choice)
    let iters = 3;
    let chunk_sizes: &[usize] = if smoke { &[4, 16] } else { &[4, 8, 16, 32] };
    let lan = LinkModel {
        name: "lan-200m",
        goodput_bps: 25e6,
        rtt: Duration::from_millis(2),
        jitter_frac: 0.0,
    };
    let wifi = LinkModel {
        // the paper's Wi-Fi 4 goodput with a scaled-down RTT so the
        // sweep stays seconds, not minutes
        name: "wifi-goodput",
        goodput_bps: 30.4e6 / 8.0,
        rtt: Duration::from_millis(10),
        jitter_frac: 0.0,
    };
    let links: Vec<LinkModel> = if smoke { vec![lan] } else { vec![lan, wifi] };

    println!("================================================================");
    println!(" streaming assembly — store-and-forward vs streamed chunk decode");
    println!(" dims {DIMS:?}, {total} rows stored, {m}-row prefix restored{}",
        if smoke { "  [smoke]" } else { "" });
    println!("================================================================");

    let st = filled_state(total);
    let cb = CacheBox::start_local().expect("cache box");
    let mut conn = KvClient::connect(&cb.addr()).expect("client");

    let mut rows_json: Vec<Json> = Vec::new();
    for &ct in chunk_sizes {
        let blob = st.serialize_prefix_opts(total, HASH, Compression::Deflate, ct);
        let lo = BlobLayout::new(HASH, l, kh, d).with_chunk_tokens(ct);
        let key = format!("state:ct{ct}");
        conn.set(key.as_bytes(), &blob).expect("seed");
        let chunk_decode = mean_chunk_decode(&blob, &lo, total, m);

        for link in &links {
            // per-metric minima across iterations: one preempted iteration
            // cannot fail the gate, and both paths get their best case
            let mut s: Option<Sample> = None;
            for _ in 0..iters {
                let (sf, _) = run_store_forward(&mut conn, link, key.as_bytes(), &lo, total, m);
                let (stm, last, overlap, wire) =
                    run_streaming(&mut conn, link, key.as_bytes(), &lo, total, m);
                let tail = stm.saturating_sub(last);
                s = Some(match s {
                    None => Sample {
                        store_forward: sf,
                        streaming: stm,
                        last_byte: last,
                        tail_decode: tail,
                        overlap_saved: overlap,
                        wire_bytes: wire,
                    },
                    Some(b) => Sample {
                        store_forward: b.store_forward.min(sf),
                        streaming: b.streaming.min(stm),
                        last_byte: b.last_byte.min(last),
                        tail_decode: b.tail_decode.min(tail),
                        overlap_saved: b.overlap_saved.max(overlap),
                        wire_bytes: wire,
                    },
                });
            }
            let s = s.unwrap();
            let ms = |dur: Duration| dur.as_secs_f64() * 1e3;
            println!(
                "ct={ct:<3} {:<12} wire {:>7.1} KB  s&f {:>8.2} ms  stream {:>8.2} ms  last-byte {:>8.2} ms  tail {:>6.3} ms  (1 chunk ≈ {:>6.3} ms)  overlap {:>6.3} ms",
                link.name,
                s.wire_bytes as f64 / 1e3,
                ms(s.store_forward),
                ms(s.streaming),
                ms(s.last_byte),
                ms(s.tail_decode),
                ms(chunk_decode),
                ms(s.overlap_saved),
            );

            // acceptance: streaming strictly beats store-and-forward at
            // every measured chunk size × link
            assert!(
                s.streaming < s.store_forward,
                "streaming ({:?}) must beat store-and-forward ({:?}) at ct={ct} on {}",
                s.streaming,
                s.store_forward,
                link.name
            );
            // acceptance: restore completes within ~1 chunk-decode of the
            // last byte (2x + a small scheduling floor absorbs timer noise)
            let bound = chunk_decode * 2 + Duration::from_millis(5);
            assert!(
                s.tail_decode <= bound,
                "tail decode {:?} exceeds ~1 chunk-decode bound {:?} at ct={ct} on {}",
                s.tail_decode,
                bound,
                link.name
            );
            assert!(
                s.overlap_saved > Duration::ZERO,
                "streamed run must credit overlap at ct={ct} on {}",
                link.name
            );

            rows_json.push(Json::obj(vec![
                ("link", Json::Str(link.name.to_string())),
                ("chunk_tokens", Json::Int(ct as i64)),
                ("entry_rows", Json::Int(total as i64)),
                ("matched_rows", Json::Int(m as i64)),
                ("wire_bytes", Json::Int(s.wire_bytes as i64)),
                ("store_forward_ms", Json::Num(ms(s.store_forward))),
                ("streaming_ms", Json::Num(ms(s.streaming))),
                ("last_byte_ms", Json::Num(ms(s.last_byte))),
                ("tail_decode_ms", Json::Num(ms(s.tail_decode))),
                ("chunk_decode_ms", Json::Num(ms(chunk_decode))),
                ("overlap_saved_ms", Json::Num(ms(s.overlap_saved))),
                (
                    "speedup_x",
                    Json::Num(s.store_forward.as_secs_f64() / s.streaming.as_secs_f64()),
                ),
            ]));
        }
    }

    let json = Json::obj(vec![
        ("smoke", Json::Bool(smoke)),
        ("dims", Json::Str(format!("{DIMS:?}"))),
        ("rows", Json::Arr(rows_json)),
    ]);
    let path = std::env::var("EDGECACHE_STREAMING_JSON")
        .unwrap_or_else(|_| "BENCH_streaming.json".into());
    match std::fs::write(&path, json.to_pretty()) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => println!("\ncould not write {path}: {e}"),
    }
    cb.shutdown();
    println!("streaming_assembly done.");
}
