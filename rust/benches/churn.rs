//! Churn acceptance bench — fleet liveness under membership churn, the
//! scripted harness for the heartbeat/deadline layer:
//!
//! * **(a) reboot waves + a permanent death, heartbeats vs ablation**: a
//!   3-box ring fabric with replicas=1 takes rolling reboots (leave +
//!   rejoin on the same address) and then loses one box for good.  The
//!   heartbeat run watches the `Up → Suspect → Dead → Recovering` machine,
//!   lets the ring heal its owner sets, and repair-sweeps the healed box —
//!   so it must end with the replication factor restored and a post-death
//!   hit rate of 1.0.  The ablation (no heartbeats, no heal, no repair)
//!   must end strictly lower — asserted.
//! * **(b) stalled peer costs one deadline budget**: an accepted-but-silent
//!   TCP endpoint claims the entry; every restore must rotate to the real
//!   replica within roughly one op budget of the single-peer control —
//!   asserted per fetch.
//! * **(c) mid-run link degradation**: seeded `FaultPlan` flap schedules
//!   (goodput degradation on one peer, stalls on the other) attached to
//!   the shapers mid-trace; every fetch must still restore bit-exact.
//!
//! Every fabric op is watchdogged: any single op slower than `WEDGE`
//! counts as wedged and fails the bench ("zero wedged operations").
//!
//! Emits `BENCH_churn.json`.
//!
//! Env: EDGECACHE_SMOKE=1 (reduced sizes for the check.sh gate),
//!      EDGECACHE_CHURN_JSON (output path, default BENCH_churn.json).

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use edgecache::coordinator::fabric::{
    fetch_prefix_multi, repair_entry, Peer, PeerConfig,
};
use edgecache::coordinator::{
    CacheBox, DeadlineBudget, HealthPolicy, Membership, PeerHealth, PeerPlanner,
    Placement, RendezvousRing,
};
use edgecache::kvstore::KvClient;
use edgecache::model::state::{Compression, KvState};
use edgecache::netsim::{Fault, FaultPlan, LinkModel};
use edgecache::util::bytes::SharedBytes;
use edgecache::util::json::Json;
use edgecache::util::rng::Rng;

const HASH: &str = "bench-churn";
const DIMS: (usize, usize, usize, usize) = (4, 128, 2, 32); // 2 KB/token
const CT: usize = 4;
/// Heartbeat cadence for the sync loops (fast so death/heal detection
/// fits a bench run; real deployments run 100-200 ms).
const SYNC_INTERVAL: Duration = Duration::from_millis(25);
/// Any single fabric op slower than this is a wedged operation.
const WEDGE: Duration = Duration::from_secs(8);

fn budget() -> DeadlineBudget {
    DeadlineBudget::from_millis(300, 400)
}

fn bench_link() -> LinkModel {
    LinkModel {
        name: "lan-64m",
        goodput_bps: 8e6,
        rtt: Duration::from_millis(2),
        jitter_frac: 0.0,
    }
}

fn filled_state(total_rows: usize, seed: u64) -> KvState {
    let (l, s, kh, d) = DIMS;
    let mut st = KvState::zeroed(l, s, kh, d);
    st.n_tokens = total_rows;
    let mut rng = Rng::new(seed);
    for x in st.k.iter_mut().take(total_rows * 2 * kh * d * l) {
        *x = rng.f64() as f32;
    }
    for x in st.v.iter_mut().take(total_rows * 2 * kh * d * l) {
        *x = rng.f64() as f32 - 0.5;
    }
    st
}

fn ms(d: Duration) -> f64 {
    d.as_secs_f64() * 1e3
}

fn p95(samples: &[f64]) -> f64 {
    if samples.is_empty() {
        return 0.0;
    }
    let mut v = samples.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    v[((v.len() - 1) as f64 * 0.95).round() as usize]
}

fn wait_for(what: &str, timeout: Duration, mut cond: impl FnMut() -> bool) {
    let t0 = Instant::now();
    while !cond() {
        assert!(t0.elapsed() < timeout, "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(10));
    }
}

/// Restart a cache box on the address its predecessor just vacated.  std's
/// `TcpListener::bind` sets SO_REUSEADDR on unix, so lingering TIME_WAIT
/// sockets don't block the rebind; retry briefly anyway for the dead
/// instance's accept thread to release the port.
fn restart_box(addr: &str) -> CacheBox {
    let t0 = Instant::now();
    loop {
        match CacheBox::start(addr, 1 << 30) {
            Ok(cb) => return cb,
            Err(e) => {
                assert!(
                    t0.elapsed() < Duration::from_secs(10),
                    "could not rebind {addr}: {e}"
                );
                std::thread::sleep(Duration::from_millis(25));
            }
        }
    }
}

struct Entry {
    key: String,
    /// Owner set under the all-alive ring (primary first, replicas=1).
    owners: Vec<usize>,
    blob: Vec<u8>,
    truth: KvState,
}

/// Generate entries until `n_target` exist *and* every owner pair of the
/// 3-box ring is covered (so each churn victim combination loses at least
/// one entry in the ablation), then seed the blobs onto their owners.
fn seed_entries(
    ring: &RendezvousRing,
    addrs: &[String],
    n_target: usize,
    rows: usize,
    m: usize,
) -> Vec<Entry> {
    let mut entries = Vec::new();
    let mut pairs_seen = [false; 3];
    for i in 0..64u64 {
        let key = format!("state:c{i}");
        let owners = ring.owners(key.as_bytes(), 1);
        assert_eq!(owners.len(), 2, "replicas=1 on 3 boxes gives 2 owners");
        let pair = owners[0] + owners[1] - 1; // {0,1}->0, {0,2}->1, {1,2}->2
        pairs_seen[pair] = true;
        let st = filled_state(rows, 1000 + i);
        let blob = st.serialize_prefix_opts(rows, HASH, Compression::None, CT);
        let truth = KvState::restore(
            &st.serialize_prefix_opts(m, HASH, Compression::None, CT),
            HASH,
            DIMS,
        )
        .expect("truth restore");
        entries.push(Entry { key, owners, blob, truth });
        if entries.len() >= n_target && pairs_seen.iter().all(|&p| p) {
            break;
        }
    }
    assert!(
        pairs_seen.iter().all(|&p| p),
        "64 keys must cover all owner pairs"
    );
    for e in &entries {
        for &o in &e.owners {
            let mut c = KvClient::connect(&addrs[o]).expect("seed conn");
            c.set(e.key.as_bytes(), &e.blob).expect("seed set");
        }
    }
    entries
}

fn claimers<'a>(peers: &'a mut [Peer], owners: &[usize]) -> Vec<(usize, &'a mut Peer)> {
    peers
        .iter_mut()
        .enumerate()
        .filter(|(i, _)| owners.contains(i))
        .collect()
}

#[derive(Default)]
struct RunStats {
    warm_hits: usize,
    warm_total: usize,
    post_hits: usize,
    post_total: usize,
    warm_ms: Vec<f64>,
    post_ms: Vec<f64>,
    republished: u64,
    max_op_ms: f64,
    wedged: usize,
    deaths: u64,
    heals: u64,
}

impl RunStats {
    fn post_rate(&self) -> f64 {
        self.post_hits as f64 / self.post_total.max(1) as f64
    }
}

/// One full churn scenario: warm pass, rolling reboot wave, permanent
/// death, post pass.  `heartbeats` arms the membership machine + sync-loop
/// heartbeats + ring heal + repair sweeps; the ablation runs the identical
/// event sequence blind.
fn run_scenario(heartbeats: bool, smoke: bool) -> RunStats {
    let (rows, m, n_entries) = if smoke { (24usize, 16usize, 4usize) } else { (40, 32, 8) };
    let reboots: Vec<usize> = if smoke { vec![0] } else { vec![0, 2] };
    let killed = 1usize;

    let mut boxes: Vec<Option<CacheBox>> = (0..3)
        .map(|_| Some(CacheBox::start_local().expect("box start")))
        .collect();
    let addrs: Vec<String> =
        boxes.iter().map(|b| b.as_ref().unwrap().addr()).collect();
    let mut ring = RendezvousRing::new(addrs.clone());
    let entries = seed_entries(&ring, &addrs, n_entries, rows, m);

    let planner = PeerPlanner::default();
    let membership = Membership::new(3, HealthPolicy::default());
    let mut peers: Vec<Peer> = addrs
        .iter()
        .enumerate()
        .map(|(i, a)| {
            let cfg = PeerConfig::new(a.clone()).with_deadline(budget());
            let mut p =
                Peer::connect(cfg, bench_link(), 10 + i as u64, 1).expect("peer connect");
            if heartbeats {
                p.set_health(membership.sink(i));
                p.spawn_sync_with(SYNC_INTERVAL, Some(membership.sink(i)))
                    .expect("sync spawn");
            }
            p
        })
        .collect();

    let mut out = RunStats::default();
    let fetch_pass = |peers: &mut [Peer],
                          owners_of: &dyn Fn(&Entry) -> Vec<usize>,
                          hits: &mut usize,
                          total: &mut usize,
                          lat: &mut Vec<f64>,
                          max_op: &mut f64,
                          wedged: &mut usize| {
        for e in &entries {
            let owners = owners_of(e);
            let t0 = Instant::now();
            let got = {
                let mut cl = claimers(peers, &owners);
                if cl.is_empty() {
                    None
                } else {
                    fetch_prefix_multi(
                        &mut cl, &planner, e.key.as_bytes(), rows, false, CT, m, HASH,
                        DIMS, None,
                    )
                }
            };
            let el = t0.elapsed();
            *max_op = max_op.max(ms(el));
            if el >= WEDGE {
                *wedged += 1;
            }
            *total += 1;
            if let Some(f) = got {
                assert_eq!(f.state.k, e.truth.k, "{}: corrupt restore", e.key);
                assert_eq!(f.state.v, e.truth.v, "{}: corrupt restore", e.key);
                *hits += 1;
            }
            lat.push(ms(el));
        }
    };

    // ---- warm pass: all boxes up, static owners ------------------------
    let static_owners = |e: &Entry| e.owners.clone();
    {
        let RunStats { warm_hits, warm_total, warm_ms, max_op_ms, wedged, .. } =
            &mut out;
        fetch_pass(
            &mut peers, &static_owners, warm_hits, warm_total, warm_ms, max_op_ms,
            wedged,
        );
    }
    assert_eq!(out.warm_hits, out.warm_total, "warm pass must fully hit");

    // ---- rolling reboot wave -------------------------------------------
    for &v in &reboots {
        boxes[v].take().expect("victim alive").shutdown();
        if heartbeats {
            // death detection rides the sync loop's missed heartbeats
            wait_for("death detection", Duration::from_secs(10), || {
                membership.state(v) == PeerHealth::Dead
            });
        } else {
            // the ablation gets the same wall-clock gap, just no observer
            std::thread::sleep(Duration::from_millis(150));
        }
        boxes[v] = Some(restart_box(&addrs[v]));
        if heartbeats {
            // the sync loop's backoff probe doubles as recovery detection:
            // Dead -> Recovering on the first heartbeat, Up after probation
            wait_for("heal", Duration::from_secs(20), || {
                membership.state(v) == PeerHealth::Up
            });
            // the pooled conn predates the reboot; drop it so the repair
            // sweep redials instead of burning its first probe on a stale
            // socket
            peers[v].mark_dead_conn();
            ring.on_membership_change(&membership.alive_flags());
            // repair sweep: re-publish every entry the reboot wiped
            for e in &entries {
                let owners = ring.owners(e.key.as_bytes(), 1);
                let mut blob = || SharedBytes::copy_from(&e.blob);
                let r = repair_entry(&mut peers, &owners, e.key.as_bytes(), None, &mut blob);
                out.republished += r.republished;
                assert_eq!(r.rejected, 0, "repair publish rejected");
            }
        }
    }
    if heartbeats {
        // the acceptance gate: after heal + repair the replication factor
        // is restored — every owner of every entry serves it again
        for e in &entries {
            for &o in &e.owners {
                let mut c = KvClient::connect(&addrs[o]).expect("verify conn");
                assert!(
                    c.exists(e.key.as_bytes()).expect("verify exists"),
                    "{} missing on owner {o} after heal+repair",
                    e.key
                );
            }
        }
        assert!(out.republished > 0, "the reboot wave must have cost replicas");
    }

    // ---- permanent death + post pass -----------------------------------
    boxes[killed].take().expect("killed box alive").shutdown();
    if heartbeats {
        wait_for("killed-peer detection", Duration::from_secs(10), || {
            membership.state(killed) == PeerHealth::Dead
        });
        ring.on_membership_change(&membership.alive_flags());
    } else {
        std::thread::sleep(Duration::from_millis(150));
    }
    {
        // heartbeat run: live owner sets (the dead box's slot fell to its
        // ring successor); ablation: the stale static owners, dead box
        // included
        let live_owners = |e: &Entry| ring.owners(e.key.as_bytes(), 1);
        let RunStats { post_hits, post_total, post_ms, max_op_ms, wedged, .. } =
            &mut out;
        if heartbeats {
            fetch_pass(
                &mut peers, &live_owners, post_hits, post_total, post_ms, max_op_ms,
                wedged,
            );
        } else {
            fetch_pass(
                &mut peers, &static_owners, post_hits, post_total, post_ms, max_op_ms,
                wedged,
            );
        }
    }
    if heartbeats {
        // final sweep restores replicas=1 on the survivors, too
        for e in &entries {
            let owners = ring.owners(e.key.as_bytes(), 1);
            let mut blob = || SharedBytes::copy_from(&e.blob);
            let r = repair_entry(&mut peers, &owners, e.key.as_bytes(), None, &mut blob);
            out.republished += r.republished;
            for &o in &owners {
                let mut c = KvClient::connect(&addrs[o]).expect("verify conn");
                assert!(
                    c.exists(e.key.as_bytes()).expect("verify exists"),
                    "{} not re-replicated onto survivor {o}",
                    e.key
                );
            }
        }
        out.deaths = membership.deaths();
        out.heals = membership.heals();
    }

    for p in &mut peers {
        p.stop_sync();
    }
    for b in boxes.into_iter().flatten() {
        b.shutdown();
    }
    out
}

/// (b) A stalled (accepted-but-silent) head claimer: every restore must
/// rotate to the live replica within about one op budget of the
/// single-peer control.
fn stalled_section(json: &mut Vec<(&'static str, Json)>) {
    let (rows, m) = (24usize, 16usize);
    let st = filled_state(rows, 77);
    let blob = st.serialize_prefix_opts(rows, HASH, Compression::None, CT);
    let truth = KvState::restore(
        &st.serialize_prefix_opts(m, HASH, Compression::None, CT),
        HASH,
        DIMS,
    )
    .expect("truth restore");
    let cb = CacheBox::start_local().expect("box");
    KvClient::connect(&cb.addr())
        .expect("seed conn")
        .set(b"state:stall", &blob)
        .expect("seed");

    // the silent peer: accepts connections, never answers, never closes
    let listener = std::net::TcpListener::bind("127.0.0.1:0").expect("silent bind");
    listener.set_nonblocking(true).expect("nonblocking");
    let silent_addr = listener.local_addr().expect("silent addr").to_string();
    let stop = Arc::new(AtomicBool::new(false));
    let stop2 = Arc::clone(&stop);
    let holder = std::thread::spawn(move || {
        let mut held = Vec::new();
        while !stop2.load(Ordering::SeqCst) {
            match listener.accept() {
                Ok((s, _)) => held.push(s),
                Err(_) => std::thread::sleep(Duration::from_millis(5)),
            }
        }
    });

    let planner = PeerPlanner::default();
    let b = budget();
    let mut real = Peer::connect(
        PeerConfig::new(cb.addr()).with_deadline(b),
        bench_link(),
        31,
        1,
    )
    .expect("real peer");
    let mut fetch_control = || {
        let t0 = Instant::now();
        let f = {
            let mut cl = vec![(1usize, &mut real)];
            fetch_prefix_multi(
                &mut cl, &planner, b"state:stall", rows, false, CT, m, HASH, DIMS, None,
            )
            .expect("control fetch")
        };
        assert_eq!(f.state.k, truth.k);
        t0.elapsed()
    };
    let control = fetch_control().min(fetch_control());

    let mut silent = Peer::connect(
        PeerConfig::new(silent_addr).with_deadline(b),
        bench_link(),
        32,
        1,
    )
    .expect("silent peer connect");
    let mut worst = Duration::ZERO;
    let n = 3;
    for i in 0..n {
        let t0 = Instant::now();
        let f = {
            // the silent peer is the preferred head every time
            let mut cl = vec![(0usize, &mut silent), (1usize, &mut real)];
            fetch_prefix_multi(
                &mut cl, &planner, b"state:stall", rows, false, CT, m, HASH, DIMS, None,
            )
        }
        .unwrap_or_else(|| panic!("stalled fetch {i} must restore via the replica"));
        let el = t0.elapsed();
        worst = worst.max(el);
        assert_eq!(f.state.k, truth.k, "stalled fetch {i}: corrupt restore");
        assert_eq!(f.state.v, truth.v);
        assert!(
            el < control + 2 * b.op,
            "stalled fetch {i} took {el:?}; budget {:?} + control {control:?} allows one \
             deadline plus slack",
            b.op
        );
    }
    assert!(
        silent.ledger.timeouts >= 1,
        "the stall must be classified as a deadline expiry, not a dead conn"
    );
    println!(
        "(b) stalled head claimer: control {:>7.2} ms, worst stalled {:>7.2} ms \
         (op budget {} ms, {} deadline expiries)",
        ms(control),
        ms(worst),
        b.op.as_millis(),
        silent.ledger.timeouts,
    );
    json.push((
        "stalled_peer",
        Json::obj(vec![
            ("op_budget_ms", Json::Int(b.op.as_millis() as i64)),
            ("control_ms", Json::Num(ms(control))),
            ("worst_ms", Json::Num(ms(worst))),
            ("fetches", Json::Int(n as i64)),
            ("deadline_expiries", Json::Int(silent.ledger.timeouts as i64)),
        ]),
    ));
    stop.store(true, Ordering::SeqCst);
    holder.join().expect("holder join");
    cb.shutdown();
}

/// (c) Mid-run link degradation: seeded flap schedules on both peers'
/// shapers; the trace keeps restoring bit-exact through the windows.
fn degraded_section(smoke: bool, json: &mut Vec<(&'static str, Json)>) {
    let (rows, m) = (24usize, 16usize);
    let n_ops = if smoke { 8u64 } else { 16 };
    let st = filled_state(rows, 88);
    let blob = st.serialize_prefix_opts(rows, HASH, Compression::None, CT);
    let truth = KvState::restore(
        &st.serialize_prefix_opts(m, HASH, Compression::None, CT),
        HASH,
        DIMS,
    )
    .expect("truth restore");
    let cb_a = CacheBox::start_local().expect("box a");
    let cb_b = CacheBox::start_local().expect("box b");
    for cb in [&cb_a, &cb_b] {
        KvClient::connect(&cb.addr())
            .expect("seed conn")
            .set(b"state:flap", &blob)
            .expect("seed");
    }
    let mut pa = Peer::connect(
        PeerConfig::new(cb_a.addr()).with_deadline(budget()),
        bench_link(),
        41,
        1,
    )
    .expect("peer a");
    let mut pb = Peer::connect(
        PeerConfig::new(cb_b.addr()).with_deadline(budget()),
        bench_link(),
        42,
        1,
    )
    .expect("peer b");
    // each fetch costs several shaped ops, so schedule over that op space
    pa.shaper
        .attach_faults(FaultPlan::flap_schedule(21, n_ops * 3, 3, Fault::Degrade(6.0)));
    pb.shaper.attach_faults(FaultPlan::flap_schedule(
        22,
        n_ops * 3,
        3,
        Fault::Stall(Duration::from_millis(120)),
    ));

    let planner = PeerPlanner::default();
    let mut lat = Vec::new();
    let mut max_op = 0.0f64;
    for i in 0..n_ops {
        let t0 = Instant::now();
        let f = {
            let mut cl: Vec<(usize, &mut Peer)> = if i % 2 == 0 {
                vec![(0, &mut pa), (1, &mut pb)]
            } else {
                vec![(1, &mut pb), (0, &mut pa)]
            };
            fetch_prefix_multi(
                &mut cl, &planner, b"state:flap", rows, false, CT, m, HASH, DIMS, None,
            )
        }
        .unwrap_or_else(|| panic!("degraded fetch {i} must still hit"));
        let el = t0.elapsed();
        assert!(el < WEDGE, "degraded fetch {i} wedged: {el:?}");
        max_op = max_op.max(ms(el));
        assert_eq!(f.state.k, truth.k, "degraded fetch {i}: corrupt restore");
        lat.push(ms(el));
    }
    let faulted = pa.shaper.faulted_ops + pb.shaper.faulted_ops;
    assert!(faulted >= 1, "the flap schedules must have fired mid-run");
    println!(
        "(c) degraded links: {n_ops} fetches through {faulted} faulted shaper ops, \
         p95 {:>7.2} ms, max {:>7.2} ms, hit rate 1.00",
        p95(&lat),
        max_op,
    );
    json.push((
        "degraded_links",
        Json::obj(vec![
            ("fetches", Json::Int(n_ops as i64)),
            ("faulted_shaper_ops", Json::Int(faulted as i64)),
            ("p95_ms", Json::Num(p95(&lat))),
            ("max_ms", Json::Num(max_op)),
            ("hit_rate", Json::Num(1.0)),
        ]),
    ));
    cb_a.shutdown();
    cb_b.shutdown();
}

fn run_json(r: &RunStats) -> Json {
    Json::obj(vec![
        ("warm_hits", Json::Int(r.warm_hits as i64)),
        ("warm_total", Json::Int(r.warm_total as i64)),
        ("post_hits", Json::Int(r.post_hits as i64)),
        ("post_total", Json::Int(r.post_total as i64)),
        ("post_hit_rate", Json::Num(r.post_rate())),
        ("warm_p95_ms", Json::Num(p95(&r.warm_ms))),
        ("post_p95_ms", Json::Num(p95(&r.post_ms))),
        ("republished", Json::Int(r.republished as i64)),
        ("max_op_ms", Json::Num(r.max_op_ms)),
        ("wedged_ops", Json::Int(r.wedged as i64)),
        ("deaths", Json::Int(r.deaths as i64)),
        ("heals", Json::Int(r.heals as i64)),
    ])
}

fn main() {
    edgecache::util::logger::init_from_env();
    let smoke = std::env::var("EDGECACHE_SMOKE").as_deref() == Ok("1");
    println!("=================================================================");
    println!(
        " churn — reboot waves, peer death, stalls, link flaps{}",
        if smoke { "  [smoke]" } else { "" }
    );
    println!("=================================================================");

    // ---- (a) churn with heartbeats vs the no-heartbeat ablation ---------
    let hb = run_scenario(true, smoke);
    let ab = run_scenario(false, smoke);
    println!(
        "(a) heartbeats: warm {}/{}, post-death {}/{} ({} republished, \
         {} deaths, {} heals, p95 warm {:.2} ms -> post {:.2} ms)",
        hb.warm_hits,
        hb.warm_total,
        hb.post_hits,
        hb.post_total,
        hb.republished,
        hb.deaths,
        hb.heals,
        p95(&hb.warm_ms),
        p95(&hb.post_ms),
    );
    println!(
        "(a) ablation:   warm {}/{}, post-death {}/{} (no heal, no repair)",
        ab.warm_hits, ab.warm_total, ab.post_hits, ab.post_total,
    );
    assert_eq!(hb.post_hits, hb.post_total, "heal+repair must retain every hit");
    assert!(
        hb.post_rate() > ab.post_rate(),
        "heartbeat run ({:.2}) must strictly beat the ablation ({:.2})",
        hb.post_rate(),
        ab.post_rate(),
    );
    assert_eq!(hb.wedged + ab.wedged, 0, "zero wedged operations");
    assert!(hb.heals >= 1, "the reboot wave must heal through Recovering");
    // tail retention: churn may cost re-plans and redials but never a
    // tail blow-up (the budgets bound every stall)
    assert!(
        p95(&hb.post_ms) < p95(&hb.warm_ms) * 20.0 + 100.0,
        "post-churn p95 {:.2} ms vs warm {:.2} ms: tail not retained",
        p95(&hb.post_ms),
        p95(&hb.warm_ms),
    );

    let mut sections: Vec<(&'static str, Json)> = vec![
        ("smoke", Json::Bool(smoke)),
        ("dims", Json::Str(format!("{DIMS:?}"))),
        ("heartbeats", run_json(&hb)),
        ("ablation", run_json(&ab)),
    ];
    stalled_section(&mut sections);
    degraded_section(smoke, &mut sections);

    let json = Json::obj(sections);
    let path = std::env::var("EDGECACHE_CHURN_JSON")
        .unwrap_or_else(|_| "BENCH_churn.json".into());
    match std::fs::write(&path, json.to_pretty()) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => println!("\ncould not write {path}: {e}"),
    }
    println!("churn done.");
}
