//! Ablation: fetch policies across the device/link design space — the
//! paper's §5.3 break-even discussion turned into a measurable sweep.
//!
//! The paper *always* fetches on a probable hit and shows that this loses on
//! the high-end device (Table 2, +7 %).  [`FetchPolicy::BreakEven`] instead
//! predicts transfer vs. local-prefill time per hit; this bench sweeps where
//! the break-even point falls for each (device, link, state-size) corner and
//! verifies the policy's end-to-end effect through the real stack.

use std::sync::Arc;

use edgecache::coordinator::{CacheBox, EdgeClient, EdgeClientConfig, FetchPolicy, HitCase};
use edgecache::devicemodel::DeviceProfile;
use edgecache::engine::Engine;
use edgecache::netsim::LinkModel;
use edgecache::report::ascii_table;
use edgecache::report::experiments as exp;
use edgecache::workload::Generator;

fn main() {
    edgecache::util::logger::init_from_env();

    // --------------------------------------------------- break-even frontier
    println!("== break-even token count per (device, link, state size) ==\n");
    let mut rows = Vec::new();
    for (dev_name, dev) in [
        ("pi-zero-2w", DeviceProfile::pi_zero_2w()),
        ("pi5-4gb", DeviceProfile::pi5_4gb()),
    ] {
        for (link_name, link) in [
            ("wifi4-2g4", LinkModel::wifi4_2g4()),
            ("ethernet-1g", LinkModel::ethernet_1g()),
        ] {
            for (model, bpt) in [("270M (34.5 KB/tok)", 34_474), ("1B (29.8 KB/tok)", 29_751)] {
                let be = FetchPolicy::break_even_tokens(&dev, &link, bpt);
                rows.push(vec![
                    dev_name.to_string(),
                    link_name.to_string(),
                    model.to_string(),
                    if be == usize::MAX { "never".into() } else { be.to_string() },
                ]);
            }
        }
    }
    println!(
        "{}",
        ascii_table(&["device", "link", "state scale", "break-even tokens"], &rows)
    );
    println!("(paper §5.3: the low-end device wins almost immediately over Wi-Fi;\n the high-end device never reasonably breaks even on Wi-Fi but would on\n a wired cache box)");

    // ------------------------------------------- policy effect on TTFT (analytic)
    println!("\n== Case-5 TTFT under Always vs BreakEven (analytic) ==\n");
    let mut rows = Vec::new();
    for s in [exp::Setting::low_end_paper(), exp::Setting::high_end_paper()] {
        let tokens = if s.name == "Low-end" { 65 } else { 334 };
        let miss = exp::analytic_breakdown(&s, tokens, 0, false);
        let hit = exp::analytic_breakdown(&s, tokens, tokens, false);
        let fetch_wins = FetchPolicy::BreakEven.should_fetch(
            &s.device,
            &s.link,
            tokens,
            tokens * s.bytes_per_token,
        );
        let be_ttft = if fetch_wins { hit.ttft() } else { miss.ttft() };
        rows.push(vec![
            s.name.to_string(),
            format!("{:.2}", miss.ttft().as_secs_f64()),
            format!("{:.2}", hit.ttft().as_secs_f64()),
            format!("{:.2}", be_ttft.as_secs_f64()),
            (if fetch_wins { "fetch" } else { "decline" }).to_string(),
        ]);
    }
    println!(
        "{}",
        ascii_table(
            &["Setting", "miss TTFT [s]", "Always-hit TTFT [s]", "BreakEven TTFT [s]", "decision"],
            &rows
        )
    );
    println!("BreakEven recovers the high-end regression (chooses local prefill)\nwhile keeping the full low-end win — strictly dominates Always.");

    // -------------------------------------------------- real-stack verification
    println!("\n== real stack: BreakEven declines fetches that lose (tiny, native) ==\n");
    let Ok(engine) = Engine::load_preset("tiny") else {
        println!("skipping (artifacts missing)");
        return;
    };
    let engine = Arc::new(engine);
    let gen = Generator::new(31);
    let p = gen.prompt("machine_learning", 0, 1);

    for (label, policy, link) in [
        ("Always on fast link", FetchPolicy::Always, LinkModel::loopback()),
        ("BreakEven on fast link", FetchPolicy::BreakEven, LinkModel::ethernet_1g()),
        (
            "BreakEven on crippled link",
            FetchPolicy::BreakEven,
            LinkModel {
                name: "crippled",
                goodput_bps: 1e5,
                rtt: std::time::Duration::from_millis(500),
                jitter_frac: 0.0,
            },
        ),
    ] {
        let cb = CacheBox::start_local().expect("cache box");
        let mut cfg = EdgeClientConfig::native(Some(cb.addr()));
        cfg.max_new_tokens = Some(2);
        cfg.sync_interval = None;
        cfg.fetch_policy = policy;
        cfg.link = link;
        let mut c = EdgeClient::new(Arc::clone(&engine), cfg).expect("client");
        let _ = c.query(&p).expect("seed");
        let r = c.query(&p).expect("repeat");
        println!(
            "  {label:<28} -> case {} ({}), declined {}",
            r.case.number(),
            if r.case == HitCase::Full { "fetched" } else { "local" },
            c.stats.fetches_declined
        );
        c.shutdown();
        cb.shutdown();
    }
}
