//! Substrate micro-benchmarks and design-choice ablations (DESIGN.md §6,
//! "Ablations" row):
//!
//! * tokenizer throughput (the Token phase must be negligible: paper 3.46 ms);
//! * RESP codec + kvstore loopback GET/SET at prompt-cache entry sizes;
//! * state-blob serialize/restore, uncompressed vs deflate (the CacheGen
//!   trade-off: CPU vs Wi-Fi bytes);
//! * the zero-copy blob pipeline: bytes *copied* per serialize→wire→store→
//!   restore round trip (copymeter) vs the seed pipeline's copy chain, plus
//!   the `GETRANGE` partial-row fetch — emitted to `BENCH_blob_pipeline.json`
//!   so the perf trajectory tracks this path;
//! * prefill chunk-size sweep on the real engine (why the artifacts ship
//!   multiple prefill variants);
//! * end-to-end upload pipeline (4-range pipelined SET+CAT.REGISTER).

use std::sync::Arc;

use edgecache::coordinator::CacheBox;
use edgecache::devicemodel::Pacer;
use edgecache::engine::Engine;
use edgecache::kvstore::KvClient;
use edgecache::metrics::PhaseBreakdown;
use edgecache::model::state::{Compression, KvState};
use edgecache::netsim::LinkModel;
use edgecache::tokenizer::Tokenizer;
use edgecache::util::rng::Rng;
use edgecache::workload::Generator;
use edgecache::xbench::{Bench, Report};

fn main() {
    edgecache::util::logger::init_from_env();
    let mut report = Report::new("substrates");

    // ------------------------------------------------------------- tokenizer
    report.section("tokenizer");
    let tok = Tokenizer::full();
    let text = Generator::new(1).prompt("astronomy", 0, 5).full_text();
    report.push(
        Bench::new(format!("encode {}-char prompt", text.len()))
            .throughput_bytes(text.len() as u64)
            .run(|| tok.encode(&text)),
    );
    let ids = tok.encode(&text);
    report.push(Bench::new("decode").run(|| tok.decode(&ids)));

    // ------------------------------------------------------------ resp codec
    report.section("RESP codec");
    let payload = vec![0xA5u8; 2_250_000]; // the paper's 270M state size
    let val = edgecache::kvstore::Value::bulk(payload.clone());
    report.push(
        Bench::new("encode 2.25MB bulk")
            .throughput_bytes(payload.len() as u64)
            .run(|| val.encode()),
    );
    let enc = val.encode();
    report.push(
        Bench::new("decode 2.25MB bulk")
            .throughput_bytes(payload.len() as u64)
            .run(|| {
                let mut d = edgecache::kvstore::resp::Decoder::new();
                d.feed(&enc);
                d.next_value().unwrap().unwrap()
            }),
    );

    // -------------------------------------------------------------- kvstore
    report.section("kvstore loopback (unshaped)");
    let cb = CacheBox::start_local().expect("cache box");
    let mut client = KvClient::connect(&cb.addr()).expect("client");
    client.set(b"bench", &payload).expect("seed");
    report.push(
        Bench::new("GET 2.25MB")
            .throughput_bytes(payload.len() as u64)
            .run(|| client.get(b"bench").unwrap()),
    );
    report.push(
        Bench::new("SET 2.25MB")
            .throughput_bytes(payload.len() as u64)
            .run(|| client.set(b"bench2", &payload).unwrap()),
    );
    report.push(Bench::new("EXISTS").run(|| client.exists(b"bench").unwrap()));
    report.note(format!(
        "wifi4-2g4 model would shape the 2.25MB GET to {:.0} ms (paper: 862 ms)",
        LinkModel::wifi4_2g4()
            .delay_for(payload.len(), None)
            .as_secs_f64()
            * 1e3
    ));

    // ------------------------------------------------------------ state blob
    report.section("KV-state blob (llama_state_get/set_data analog)");
    let mut rng = Rng::new(9);
    let mut st = KvState::zeroed(6, 768, 1, 80); // edge-270m dims
    st.n_tokens = 117; // the mean low-end prompt in our workload
    for x in st.k.iter_mut().take(117 * 80) {
        *x = rng.f64() as f32;
    }
    let plain = st.serialize("h", Compression::None);
    report.push(
        Bench::new(format!("serialize ({} KB)", plain.len() / 1024))
            .throughput_bytes(plain.len() as u64)
            .run(|| st.serialize("h", Compression::None)),
    );
    report.push(
        Bench::new("restore")
            .throughput_bytes(plain.len() as u64)
            .run(|| KvState::restore(&plain, "h", (6, 768, 1, 80)).unwrap()),
    );
    let packed = st.serialize("h", Compression::Deflate);
    report.push(
        Bench::new(format!("serialize+deflate ({} KB)", packed.len() / 1024))
            .throughput_bytes(plain.len() as u64)
            .run(|| st.serialize("h", Compression::Deflate)),
    );
    report.note(format!(
        "deflate ratio {:.2}x; on wifi4-2g4 it saves {:.0} ms of transfer per state",
        plain.len() as f64 / packed.len() as f64,
        (LinkModel::wifi4_2g4().delay_for(plain.len(), None).as_secs_f64()
            - LinkModel::wifi4_2g4().delay_for(packed.len(), None).as_secs_f64())
            * 1e3
    ));

    // --------------------------------------------------- zero-copy pipeline
    report.section("blob pipeline (serialize → wire → store → restore)");
    {
        use edgecache::model::state::{read_chunk_index, BlobLayout};
        use edgecache::util::bytes::{copymeter, SharedBytes};
        use edgecache::util::json::Json;

        let dims = (6, 768, 1, 80);
        let lo = BlobLayout::new("h", 6, 1, 80);
        let shared = st.serialize_shared("h", Compression::None);

        // one instrumented round trip: count every payload-sized memcpy
        copymeter::reset();
        let measured = st.serialize_shared("h", Compression::None);
        client.set_shared(b"pipe", measured.clone()).expect("set");
        let got = client.get(b"pipe").expect("get").expect("present");
        let back = KvState::restore(&got, "h", dims).unwrap();
        assert_eq!(back.n_tokens, st.n_tokens);
        let copied = copymeter::get();
        // the seed pipeline moved every payload byte ~11 times between
        // KvState::serialize and the restored state: gather, writer copy,
        // clone into the command, client encode, server decode, GET-reply
        // clone, reply encode, client decode, restore body copy, f32
        // conversion, scatter
        let seed_copies = 11u64 * shared.len() as u64;
        let reduction = seed_copies as f64 / copied.max(1) as f64;
        report.note(format!(
            "round trip: blob {} KB, {} KB copied ({:.1}x blob) vs seed model {:.1}x — {:.1}x fewer bytes copied",
            shared.len() / 1024,
            copied / 1024,
            copied as f64 / shared.len() as f64,
            11.0,
            reduction
        ));

        // range path: fetch only the first half of the token rows — the
        // head (header + chunk index), then the whole chunks covering them
        let m = st.n_tokens / 2;
        let total = st.n_tokens;
        let stride = lo.token_stride();
        let head_len = lo.payload_off(total);
        let fetch_rows = lo.prefix_rows(m, total);
        let head = client
            .getrange(b"pipe", 0, head_len)
            .expect("head")
            .expect("present");
        let rows = client
            .getrange(b"pipe", head_len, fetch_rows * stride)
            .expect("rows")
            .expect("present");
        let part = KvState::restore_prefix_from_parts(&head, &rows, m, "h", dims).unwrap();
        assert_eq!(part.n_tokens, m);
        let partial_bytes = head.len() + rows.len();
        report.note(format!(
            "partial fetch ({m}/{total} rows, ct={}): {} KB over the wire vs {} KB full blob",
            lo.chunk_tokens,
            partial_bytes / 1024,
            shared.len() / 1024
        ));

        report.push(
            Bench::new("zero-copy SET+GET+restore")
                .throughput_bytes(shared.len() as u64)
                .run(|| {
                    client.set_shared(b"pipe", shared.clone()).unwrap();
                    let g = client.get(b"pipe").unwrap().unwrap();
                    KvState::restore(&g, "h", dims).unwrap()
                }),
        );
        report.push(
            Bench::new(format!("GETRANGE {m}-row prefix + assemble"))
                .throughput_bytes(partial_bytes as u64)
                .run(|| {
                    let h = client.getrange(b"pipe", 0, head_len).unwrap().unwrap();
                    let r = client
                        .getrange(b"pipe", head_len, fetch_rows * stride)
                        .unwrap()
                        .unwrap();
                    KvState::restore_prefix_from_parts(&h, &r, m, "h", dims).unwrap()
                }),
        );

        // chunk-compressed range path (ECS3 deflate): the partial fetch
        // moves only the matched chunks' *compressed* bytes — the path the
        // old pipeline served with a full-blob download
        let packed_shared = SharedBytes::new(st.serialize("h", Compression::Deflate));
        client.set_shared(b"pipe-z", packed_shared.clone()).expect("set");
        let zhead = client.getrange(b"pipe-z", 0, head_len).unwrap().unwrap();
        let (zct, zentries) = read_chunk_index(&zhead).expect("v3 head");
        let zk = lo.prefix_chunks(m);
        let zspan: usize = zentries.iter().take(zk).map(|e| e.len as usize).sum();
        let zrows = client.getrange(b"pipe-z", head_len, zspan).unwrap().unwrap();
        let zpart = KvState::restore_prefix_from_parts(&zhead, &zrows, m, "h", dims).unwrap();
        assert_eq!(zpart.n_tokens, m);
        let z_partial = zhead.len() + zrows.len();
        report.note(format!(
            "deflate partial fetch ({m}/{total} rows, ct={zct}): {} KB vs {} KB deflated entry ({} KB raw)",
            z_partial / 1024,
            packed_shared.len() / 1024,
            shared.len() / 1024
        ));
        report.push(
            Bench::new(format!("GETRANGE {m}-row deflated chunks + assemble"))
                .throughput_bytes(z_partial as u64)
                .run(|| {
                    let h = client.getrange(b"pipe-z", 0, head_len).unwrap().unwrap();
                    let r = client.getrange(b"pipe-z", head_len, zspan).unwrap().unwrap();
                    KvState::restore_prefix_from_parts(&h, &r, m, "h", dims).unwrap()
                }),
        );

        // machine-readable trajectory record
        let json = Json::obj(vec![
            ("blob_bytes", Json::Int(shared.len() as i64)),
            ("roundtrip_copied_bytes", Json::Int(copied as i64)),
            ("seed_model_copied_bytes", Json::Int(seed_copies as i64)),
            ("copy_reduction_x", Json::Num(reduction)),
            ("partial_rows", Json::Int(m as i64)),
            ("total_rows", Json::Int(st.n_tokens as i64)),
            ("chunk_tokens", Json::Int(lo.chunk_tokens as i64)),
            ("partial_fetch_bytes", Json::Int(partial_bytes as i64)),
            ("full_fetch_bytes", Json::Int(shared.len() as i64)),
            ("deflate_entry_bytes", Json::Int(packed_shared.len() as i64)),
            ("deflate_partial_fetch_bytes", Json::Int(z_partial as i64)),
        ]);
        let path = std::env::var("EDGECACHE_BLOB_PIPELINE_JSON")
            .unwrap_or_else(|_| "BENCH_blob_pipeline.json".into());
        match std::fs::write(&path, json.to_pretty()) {
            Ok(()) => report.note(format!("wrote {path}")),
            Err(e) => report.note(format!("could not write {path}: {e}")),
        }
    }

    // ------------------------------------------------ prefill chunk ablation
    report.section("prefill chunk-size sweep (tiny preset, real engine)");
    match Engine::load_preset("tiny") {
        Ok(engine) => {
            let engine = Arc::new(engine);
            let prompt = Generator::new(3).prompt("astronomy", 0, 1);
            let tokens = engine.tokenize_prompt(&prompt.full_text());
            for chunk in engine.model.chunks() {
                // force a single chunk size by monkey-patching via env not
                // possible; emulate by chunk-looping manually
                let e2 = Arc::clone(&engine);
                let toks = tokens.clone();
                let stats = Bench::new(format!(
                    "prefill {} tokens in chunks of {chunk}",
                    tokens.len()
                ))
                .iters(5)
                .run(move || {
                    let mut state = e2.fresh_state();
                    let mut piece = vec![0i32; chunk];
                    let mut pos = 0usize;
                    while pos < toks.len() {
                        let valid = (toks.len() - pos).min(chunk);
                        for (i, p) in piece.iter_mut().enumerate() {
                            *p = if i < valid { toks[pos + i] as i32 } else { 0 };
                        }
                        let out = e2
                            .model
                            .prefill(chunk, &state.k, &state.v, &piece, pos as i32, valid as i32)
                            .unwrap();
                        state.k = out.kcache;
                        state.v = out.vcache;
                        pos += valid;
                    }
                    state.n_tokens = toks.len();
                    state
                });
                report.push(stats);
            }

            // --------------------------------------------- generate baseline
            report.section("end-to-end generate (tiny, native)");
            let mut pacer = Pacer::new(edgecache::devicemodel::DeviceProfile::host());
            let text = prompt.full_text();
            let e3 = Arc::clone(&engine);
            report.push(
                Bench::new("generate 4 tokens (miss path)")
                    .iters(5)
                    .run(move || e3.generate(&text, 4, &mut pacer).unwrap()),
            );

            // ------------------------------------------------ upload pipeline
            report.section("upload pipeline (4 ranges, pipelined)");
            let mut kv = KvClient::connect(&cb.addr()).expect("client");
            let mut state = engine.fresh_state();
            let mut bd = PhaseBreakdown::default();
            let mut pacer = Pacer::new(edgecache::devicemodel::DeviceProfile::host());
            engine
                .prefill_suffix(&mut state, &tokens, &mut pacer, &mut bd)
                .unwrap();
            let lens = [
                tokens.len() / 4,
                tokens.len() / 2,
                3 * tokens.len() / 4,
                tokens.len(),
            ];
            let hash = engine.model_hash().to_string();
            let total: usize = lens
                .iter()
                .map(|&l| state.serialize_prefix(l, &hash, Compression::None).len())
                .sum();
            report.push(
                Bench::new("serialize+SET 4 nested ranges")
                    .iters(10)
                    .throughput_bytes(total as u64)
                    .run(|| {
                        let cmds: Vec<Vec<Vec<u8>>> = lens
                            .iter()
                            .enumerate()
                            .map(|(i, &l)| {
                                vec![
                                    b"SET".to_vec(),
                                    format!("bench:range:{i}").into_bytes(),
                                    state.serialize_prefix(l, &hash, Compression::None),
                                ]
                            })
                            .collect();
                        kv.pipeline(&cmds).unwrap()
                    }),
            );
        }
        Err(e) => report.note(format!("engine benches skipped: {e}")),
    }

    report.finish();
    cb.shutdown();
    println!("\nsubstrate_micro done.");
}
