//! Fleet-scale serving bench — thousands of simulated clients with Zipf
//! key popularity against a small multi-box fabric, ramping concurrency
//! until each serving core breaks:
//!
//! * **threads** — the PR 1–8 ablation: thread-per-connection over
//!   blocking sockets, single store lock, unbounded admission;
//! * **poll** — the fleet-scale core: non-blocking readiness loop +
//!   worker pool, sharded store locks, bounded admission shedding `BUSY`.
//!
//! Each ramp step replays the *same* seeded trace (per-client Zipf key
//! streams over a shared key population) through both cores and records
//! per-op TTFT (request issue → reply decoded).  A step is **sustained**
//! when every simulated client finishes its stream (zero wedged) and the
//! p99 TTFT stays under the SLO.  A `BUSY` shed grants the op exactly one
//! immediate retry — the client-side one-free-replan discipline — before
//! it is counted shed and skipped.
//!
//! Simulated clients are multiplexed over a bounded pool of real
//! connections (fd-limit aware: `workers × boxes` sockets, never one per
//! simulated client); concurrency on the wire is the worker count, while
//! the key streams preserve per-client locality.
//!
//! Emits `BENCH_fleet.json`: per step p50/p99/p999 TTFT, hit rate, shed
//! rate, wedged count, per-box saturation (ops, sheds, peak pending), and
//! the cross-core verdict (max sustained clients; p99 at the highest
//! mutually-sustained step).  The full run asserts the poll core strictly
//! beats the ablation on tail latency at that step, sustains at least as
//! many clients, never wedges a client, and matches hit rate.
//!
//! Env: EDGECACHE_SMOKE=1 (reduced sizes + mechanics-only assertions for
//!      the check.sh gate), EDGECACHE_FLEET_JSON (output path, default
//!      BENCH_fleet.json).

use std::time::{Duration, Instant};

use edgecache::kvstore::{KvClient, KvServer, ServeMode, Value};
use edgecache::kvstore::resp::request;
use edgecache::util::json::Json;
use edgecache::util::rng::Rng;

// ------------------------------------------------------------ workload --

/// Zipf(s) sampler over `n` ranked keys via inverse-CDF binary search.
struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    fn new(n: usize, s: f64) -> Zipf {
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for i in 0..n {
            acc += 1.0 / ((i + 1) as f64).powf(s);
            cdf.push(acc);
        }
        let total = acc;
        for c in cdf.iter_mut() {
            *c /= total;
        }
        Zipf { cdf }
    }

    fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.f64();
        self.cdf.partition_point(|&c| c < u).min(self.cdf.len() - 1)
    }
}

/// Rank → key id permutation so the hot head of the Zipf distribution is
/// spread across the key space (and therefore across boxes/shards) instead
/// of clustering on consecutive ids.
fn scatter(rank: usize, keys: usize) -> usize {
    rank.wrapping_mul(2654435761) % keys
}

fn key_name(id: usize) -> Vec<u8> {
    format!("fleet:{id:06}").into_bytes()
}

fn key_box(id: usize, boxes: usize) -> usize {
    // FNV-1a over the id bytes — a stable placement independent of the
    // client count, so every ramp step agrees where each key lives
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in key_name(id) {
        h ^= b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    (h % boxes as u64) as usize
}

fn key_value(id: usize, val_len: usize) -> Vec<u8> {
    let len = val_len / 2 + (id * 31) % (val_len / 2).max(1);
    vec![(id % 251) as u8; len.max(1)]
}

/// One simulated client's scripted key stream.
fn client_trace(client: usize, ops: usize, zipf: &Zipf, keys: usize, seed: u64) -> Vec<usize> {
    let mut rng = Rng::new(seed ^ (client as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15));
    (0..ops).map(|_| scatter(zipf.sample(&mut rng), keys)).collect()
}

// ------------------------------------------------------------- metrics --

fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() - 1) as f64 * p).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

#[derive(Default)]
struct StepResult {
    clients: usize,
    ttft_ms: Vec<f64>,
    hits: u64,
    misses: u64,
    sheds: u64,
    busy_retries_saved: u64,
    wedged: u64,
    wall_s: f64,
    per_box: Vec<BoxStat>,
}

struct BoxStat {
    ops: u64,
    sheds: u64,
    peak_pending: u64,
}

impl StepResult {
    fn sorted_ttft(&self) -> Vec<f64> {
        let mut v = self.ttft_ms.clone();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        v
    }

    fn hit_rate(&self) -> f64 {
        let n = self.hits + self.misses;
        if n == 0 {
            0.0
        } else {
            self.hits as f64 / n as f64
        }
    }

    fn shed_rate(&self) -> f64 {
        let n = self.hits + self.misses + self.sheds;
        if n == 0 {
            0.0
        } else {
            self.sheds as f64 / n as f64
        }
    }

    fn to_json(&self) -> Json {
        let s = self.sorted_ttft();
        Json::obj(vec![
            ("clients", Json::Int(self.clients as i64)),
            ("ops", Json::Int(self.ttft_ms.len() as i64)),
            ("p50_ttft_ms", Json::Num(percentile(&s, 0.50))),
            ("p99_ttft_ms", Json::Num(percentile(&s, 0.99))),
            ("p999_ttft_ms", Json::Num(percentile(&s, 0.999))),
            ("hit_rate", Json::Num(self.hit_rate())),
            ("shed_rate", Json::Num(self.shed_rate())),
            ("busy_retries_saved", Json::Int(self.busy_retries_saved as i64)),
            ("wedged_clients", Json::Int(self.wedged as i64)),
            ("wall_s", Json::Num(self.wall_s)),
            (
                "per_box",
                Json::Arr(
                    self.per_box
                        .iter()
                        .map(|b| {
                            Json::obj(vec![
                                ("ops", Json::Int(b.ops as i64)),
                                ("sheds", Json::Int(b.sheds as i64)),
                                ("peak_pending", Json::Int(b.peak_pending as i64)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

// ------------------------------------------------------------- harness --

struct Scale {
    boxes: usize,
    shards: usize,
    max_pending: usize,
    workers: usize,
    keys: usize,
    val_len: usize,
    ops_per_client: usize,
    ramp: Vec<usize>,
    slo_ms: f64,
}

/// Drive one ramp step: `clients` simulated clients multiplexed over
/// `scale.workers` worker threads (each holding one real connection per
/// box), replaying the seeded trace against a fresh fleet in `mode`.
fn run_step(mode: ServeMode, scale: &Scale, clients: usize, zipf: &Zipf) -> StepResult {
    let (shards, max_pending) = match mode {
        ServeMode::Threads => (1, 0),
        ServeMode::Poll => (scale.shards, scale.max_pending),
    };
    let handles: Vec<_> = (0..scale.boxes)
        .map(|_| {
            KvServer::configure(usize::MAX, shards, max_pending)
                .serve_with("127.0.0.1:0", mode)
                .expect("bind fleet box")
        })
        .collect();
    let addrs: Vec<String> = handles.iter().map(|h| h.addr_string()).collect();

    let workers = scale.workers.min(clients).max(1);
    let t0 = Instant::now();
    let results: Vec<(Vec<f64>, u64, u64, u64, u64, u64)> = std::thread::scope(|s| {
        let mut joins = Vec::new();
        for w in 0..workers {
            let addrs = &addrs;
            let scale_ref = &scale;
            joins.push(s.spawn(move || {
                let mut conns: Vec<KvClient> = addrs
                    .iter()
                    .map(|a| {
                        let c = KvClient::connect(a).expect("dial fleet box");
                        c.set_io_timeout(Some(Duration::from_secs(10))).ok();
                        c
                    })
                    .collect();
                let mut ttft = Vec::new();
                let (mut hits, mut misses, mut sheds, mut saved) = (0u64, 0u64, 0u64, 0u64);
                let mut wedged = 0u64;
                // this worker's slice of the simulated-client population,
                // streams interleaved round-robin so in-flight work mixes
                // clients the way a real box sees it
                let my: Vec<Vec<usize>> = (w..clients)
                    .step_by(workers)
                    .map(|c| {
                        client_trace(c, scale_ref.ops_per_client, zipf, scale_ref.keys, 42)
                    })
                    .collect();
                'clients: for op in 0..scale_ref.ops_per_client {
                    for trace in &my {
                        let id = trace[op];
                        let b = key_box(id, addrs.len());
                        let key = key_name(id);
                        match fetch_once(&mut conns[b], &key) {
                            Fetch::Hit(ms) => {
                                hits += 1;
                                ttft.push(ms);
                            }
                            Fetch::Miss(ms) => {
                                misses += 1;
                                ttft.push(ms);
                                // populate so later touches of this hot key
                                // hit — the cache-fill half of the workload
                                let val = key_value(id, scale_ref.val_len);
                                if conns[b].set(&key, &val).is_err() {
                                    wedged += 1;
                                    break 'clients;
                                }
                            }
                            Fetch::Busy => {
                                // one free retry per op (the fabric's
                                // absent-claimer discipline applied to
                                // sheds), then count it shed and move on
                                std::thread::yield_now();
                                match fetch_once(&mut conns[b], &key) {
                                    Fetch::Hit(ms) => {
                                        hits += 1;
                                        saved += 1;
                                        ttft.push(ms);
                                    }
                                    Fetch::Miss(ms) => {
                                        misses += 1;
                                        saved += 1;
                                        ttft.push(ms);
                                    }
                                    Fetch::Busy => sheds += 1,
                                    Fetch::Dead => {
                                        wedged += 1;
                                        break 'clients;
                                    }
                                }
                            }
                            Fetch::Dead => {
                                wedged += 1;
                                break 'clients;
                            }
                        }
                    }
                }
                (ttft, hits, misses, sheds, saved, wedged)
            }));
        }
        joins.into_iter().map(|j| j.join().expect("worker panicked")).collect()
    });
    let wall_s = t0.elapsed().as_secs_f64();

    let mut out = StepResult { clients, wall_s, ..Default::default() };
    for (ttft, hits, misses, sheds, saved, wedged) in results {
        out.ttft_ms.extend(ttft);
        out.hits += hits;
        out.misses += misses;
        out.sheds += sheds;
        out.busy_retries_saved += saved;
        out.wedged += wedged;
    }
    for h in handles {
        out.per_box.push(BoxStat {
            ops: h.server.store.hits() + h.server.store.misses(),
            sheds: h.server.admission.sheds(),
            peak_pending: h.server.admission.peak_pending() as u64,
        });
        h.shutdown();
    }
    out
}

enum Fetch {
    Hit(f64),
    Miss(f64),
    Busy,
    Dead,
}

/// One timed GET: TTFT is issue → reply decoded.  Server errors come back
/// in-place (`pipeline_req`), so a `BUSY` shed is distinguishable from a
/// dead connection.
fn fetch_once(conn: &mut KvClient, key: &[u8]) -> Fetch {
    let req = request(&[b"GET" as &[u8], key]);
    let t = Instant::now();
    match conn.pipeline_req(std::slice::from_ref(&req)) {
        Ok(mut replies) => match replies.pop() {
            Some(Value::Bulk(_)) => Fetch::Hit(t.elapsed().as_secs_f64() * 1e3),
            Some(Value::Nil) => Fetch::Miss(t.elapsed().as_secs_f64() * 1e3),
            Some(Value::Error(e)) if e.starts_with("BUSY") => Fetch::Busy,
            _ => Fetch::Dead,
        },
        Err(_) => Fetch::Dead,
    }
}

fn run_mode(mode: ServeMode, scale: &Scale, zipf: &Zipf) -> (Vec<StepResult>, usize) {
    let mut steps = Vec::new();
    let mut max_sustained = 0usize;
    for &c in &scale.ramp {
        let step = run_step(mode, scale, c, zipf);
        let sorted = step.sorted_ttft();
        let p99 = percentile(&sorted, 0.99);
        let sustained = step.wedged == 0 && p99 <= scale.slo_ms;
        println!(
            "  {} @ {:>5} clients: p50 {:.3} ms, p99 {:.3} ms, p999 {:.3} ms, \
             hit {:.3}, shed {:.4}, wedged {}, {:.1} s {}",
            mode.name(),
            c,
            percentile(&sorted, 0.50),
            p99,
            percentile(&sorted, 0.999),
            step.hit_rate(),
            step.shed_rate(),
            step.wedged,
            step.wall_s,
            if sustained { "[sustained]" } else { "[broke]" },
        );
        steps.push(step);
        if sustained {
            max_sustained = c;
        } else {
            break; // past the knee — higher steps only get worse
        }
    }
    (steps, max_sustained)
}

fn main() {
    let smoke = std::env::var("EDGECACHE_SMOKE").as_deref() == Ok("1");
    let scale = if smoke {
        Scale {
            boxes: 2,
            shards: 4,
            max_pending: 256,
            workers: 16,
            keys: 128,
            val_len: 2 << 10,
            ops_per_client: 25,
            ramp: vec![8, 32],
            slo_ms: 1e9, // smoke gates mechanics, not performance
        }
    } else {
        Scale {
            boxes: 2,
            shards: 8,
            max_pending: 1024,
            workers: 128,
            keys: 4096,
            val_len: 8 << 10,
            ops_per_client: 40,
            ramp: vec![128, 512, 1024, 2048, 4096],
            slo_ms: 80.0,
        }
    };
    println!(
        "== fleet serving bench == ({} boxes, {} workers, {} keys, Zipf 1.1{})",
        scale.boxes,
        scale.workers,
        scale.keys,
        if smoke { ", SMOKE" } else { "" }
    );
    let zipf = Zipf::new(scale.keys, 1.1);

    println!("threads core (ablation: 1 shard, unbounded admission):");
    let (threads_steps, threads_max) = run_mode(ServeMode::Threads, &scale, &zipf);
    println!("poll core ({} shards, {} pending cap):", scale.shards, scale.max_pending);
    let (poll_steps, poll_max) = run_mode(ServeMode::Poll, &scale, &zipf);

    // the verdict is read at the highest step BOTH cores sustained
    let both = threads_max.min(poll_max);
    let at = |steps: &[StepResult]| -> Option<(f64, f64)> {
        steps
            .iter()
            .find(|s| s.clients == both)
            .map(|s| (percentile(&s.sorted_ttft(), 0.99), s.hit_rate()))
    };
    let (threads_p99, threads_hr) = at(&threads_steps).unwrap_or((0.0, 0.0));
    let (poll_p99, poll_hr) = at(&poll_steps).unwrap_or((0.0, 0.0));
    println!(
        "\nmax sustained: threads {} / poll {} clients; \
         @{} clients p99 TTFT threads {:.3} ms vs poll {:.3} ms",
        threads_max, poll_max, both, threads_p99, poll_p99
    );

    // -- mechanics gates (every run, smoke included) ----------------------
    for (name, steps) in [("threads", &threads_steps), ("poll", &poll_steps)] {
        for s in steps {
            let expected = (s.hits + s.misses + s.sheds) as usize;
            assert_eq!(
                s.ttft_ms.len() + s.sheds as usize,
                expected,
                "{name}: ops lost without a verdict at {} clients",
                s.clients
            );
        }
    }
    let poll_last = poll_steps.last().expect("poll ran at least one step");
    assert_eq!(poll_last.wedged, 0, "poll core wedged a client");
    assert!(poll_max >= scale.ramp[0], "poll core failed the very first step");

    // -- performance gates (full run only: smoke scale is noise) ----------
    if !smoke {
        assert!(
            poll_max >= threads_max,
            "poll sustained fewer clients ({poll_max}) than the ablation ({threads_max})"
        );
        if both > 0 {
            assert!(
                poll_p99 < threads_p99,
                "poll p99 TTFT {poll_p99:.3} ms not strictly under threads {threads_p99:.3} ms \
                 at {both} clients"
            );
            assert!(
                (poll_hr - threads_hr).abs() < 0.05,
                "hit rates diverged: poll {poll_hr:.3} vs threads {threads_hr:.3}"
            );
        } else {
            // vacuous win: the ablation broke at the very first ramp step
            println!("no mutually-sustained step — ablation broke immediately");
        }
    }

    let json = Json::obj(vec![
        ("bench", Json::str("fleet")),
        ("smoke", Json::Bool(smoke)),
        ("boxes", Json::Int(scale.boxes as i64)),
        ("workers", Json::Int(scale.workers as i64)),
        ("keys", Json::Int(scale.keys as i64)),
        ("zipf_s", Json::Num(1.1)),
        ("slo_ms", Json::Num(scale.slo_ms)),
        ("threads", Json::Arr(threads_steps.iter().map(|s| s.to_json()).collect())),
        ("poll", Json::Arr(poll_steps.iter().map(|s| s.to_json()).collect())),
        (
            "verdict",
            Json::obj(vec![
                ("max_sustained_threads", Json::Int(threads_max as i64)),
                ("max_sustained_poll", Json::Int(poll_max as i64)),
                ("mutual_clients", Json::Int(both as i64)),
                ("threads_p99_ttft_ms", Json::Num(threads_p99)),
                ("poll_p99_ttft_ms", Json::Num(poll_p99)),
                ("threads_hit_rate", Json::Num(threads_hr)),
                ("poll_hit_rate", Json::Num(poll_hr)),
            ]),
        ),
    ]);
    let path = std::env::var("EDGECACHE_FLEET_JSON")
        .unwrap_or_else(|_| "BENCH_fleet.json".to_string());
    match std::fs::write(&path, json.to_pretty()) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => println!("could not write {path}: {e}"),
    }
    println!("OK");
}
