//! Regenerates the catalog experiments:
//!
//! * **§5.2.3 (benefit of the local Bloom filter)** — per-query lookup cost
//!   with the local catalog vs remote EXISTS probing over the shaped Wi-Fi
//!   link, across hit ratios: without the catalog every inference pays
//!   round-trip overhead; with it, misses cost microseconds locally.
//! * **§5.2.4 (false-positive impact)** — expected Case-1 TTFT inflation as
//!   a function of the Bloom FP rate (analytic: fp × download), plus a real
//!   forced-FP measurement through the stack.
//! * Bloom micro-costs (insert / query / serialize) backing the paper's
//!   "0.30 ms Bloom" row and the 1.20 MB / 1 M / 1 % sizing claim.

use std::sync::Arc;

use edgecache::bloom::BloomFilter;
use edgecache::coordinator::{CacheBox, EdgeClient, EdgeClientConfig};
use edgecache::devicemodel::DeviceProfile;
use edgecache::engine::Engine;
use edgecache::netsim::LinkModel;
use edgecache::report::ascii_table;
use edgecache::report::experiments as exp;
use edgecache::workload::Generator;
use edgecache::xbench::{Bench, Report};

fn main() {
    edgecache::util::logger::init_from_env();

    // ---------------------------------------------------------------- sizing
    println!("== catalog sizing (paper §4: 1M entries @ 1% -> 1.20 MB) ==\n");
    let mut rows = Vec::new();
    for (cap, fp) in [
        (100_000u64, 0.01),
        (1_000_000, 0.01),
        (1_000_000, 0.001),
        (10_000_000, 0.01),
    ] {
        let b = BloomFilter::new(cap, fp);
        rows.push(vec![
            format!("{cap}"),
            format!("{fp}"),
            format!("{:.2}", b.size_bytes() as f64 / 1e6),
            b.k().to_string(),
        ]);
    }
    println!(
        "{}",
        ascii_table(&["capacity", "target FP", "size [MB]", "k"], &rows)
    );

    // ------------------------------------------------------------ micro cost
    println!("== bloom operation micro-costs (paper Table 3: Bloom = 0.30 ms on a Pi Zero) ==\n");
    let mut report = Report::new("bloom-ops");
    let mut filter = BloomFilter::paper_default();
    let keys: Vec<Vec<u8>> = (0..10_000).map(|i| format!("key-{i}").into_bytes()).collect();
    let mut i = 0usize;
    report.push(Bench::new("insert (1M-capacity filter)").run(|| {
        i = (i + 1) % keys.len();
        filter.insert(&keys[i])
    }));
    let mut j = 0usize;
    report.push(Bench::new("query hit").run(|| {
        j = (j + 1) % keys.len();
        filter.contains(&keys[j])
    }));
    report.push(Bench::new("query miss").run(|| filter.contains(b"never-inserted-key")));
    report.push(
        Bench::new("serialize 1.20 MB filter")
            .throughput_bytes(filter.size_bytes() as u64)
            .run(|| filter.to_bytes()),
    );
    report.finish();

    // ------------------------------------------------- §5.2.3 catalog benefit
    println!("\n== §5.2.3 — lookup cost per query: local catalog vs remote probing ==\n");
    let link = LinkModel::wifi4_2g4();
    let lo = DeviceProfile::pi_zero_2w();
    let mut rows = Vec::new();
    for hit_ratio in [0.0, 0.25, 0.5, 0.75, 1.0] {
        // with catalog: Bloom lookup always local; Redis only on (probable) hits
        let with = lo.bloom_ms_per_lookup + hit_ratio * 0.0; // download cost counted in Redis phase either way
        // without: probe the server — up to 4 EXISTS round trips on a miss,
        // expected ~(1 + (1-hit)*3) probes finding the longest range
        let probes = 1.0 + (1.0 - hit_ratio) * 3.0;
        let without = probes * link.rtt.as_secs_f64() * 1e3;
        rows.push(vec![
            format!("{:.0}%", hit_ratio * 100.0),
            format!("{with:.3}"),
            format!("{without:.1}"),
            format!("{:.0}x", without / with.max(1e-9)),
        ]);
    }
    println!(
        "{}",
        ascii_table(
            &["hit ratio", "with catalog [ms]", "without (probe) [ms]", "saving"],
            &rows
        )
    );
    println!("(paper: \"without the catalog, every inference would incur the Redis\n access overhead\" — the probing column is exactly that overhead)");

    // --------------------------------------------------- §5.2.4 FP-rate sweep
    println!("\n== §5.2.4 — expected Case-1 TTFT inflation vs Bloom FP rate ==\n");
    let mut rows = Vec::new();
    for fp in [0.001, 0.01, 0.05, 0.1, 0.25] {
        let mut s = exp::Setting::low_end_paper();
        s.fp_rate = fp;
        let bd = exp::analytic_breakdown(&s, 65, 0, true);
        let base = exp::analytic_breakdown(
            &exp::Setting { fp_rate: 0.0, ..exp::Setting::low_end_paper() },
            65,
            0,
            true,
        );
        let inflation =
            bd.ttft().as_secs_f64() - base.ttft().as_secs_f64();
        rows.push(vec![
            format!("{fp}"),
            format!("{:.1}", inflation * 1e3),
            format!("{:.3}", inflation / base.ttft().as_secs_f64() * 100.0),
        ]);
    }
    println!(
        "{}",
        ascii_table(
            &["FP rate", "TTFT inflation [ms]", "relative [%]"],
            &rows
        )
    );
    println!("(paper: at 1 % the expected cost is 0.86 s x 0.01 ≈ 8.6 ms — negligible)");

    // -------------------------------------------------- real forced-FP check
    println!("\n== real forced-FP measurement (tiny preset, native) ==\n");
    let Ok(engine) = Engine::load_preset("tiny") else {
        println!("skipping (artifacts missing)");
        return;
    };
    let engine = Arc::new(engine);
    let cb = CacheBox::start_local().expect("cache box");
    let mut cfg = EdgeClientConfig::native(Some(cb.addr()));
    cfg.max_new_tokens = Some(2);
    cfg.sync_interval = None;
    let mut client = EdgeClient::new(Arc::clone(&engine), cfg).expect("client");
    let gen = Generator::new(7);

    // clean miss
    let p_clean = gen.prompt("philosophy", 0, 1);
    let r_clean = client.query(&p_clean).expect("clean");

    // poisoned miss (every range falsely marked present)
    let p_fp = gen.prompt("moral_disputes", 0, 1);
    {
        let tokens = engine.tokenize_prompt(&p_fp.full_text());
        let meta = edgecache::catalog::ModelMeta::new(engine.model_hash());
        let ranges = edgecache::catalog::ranges_for(
            &meta,
            &tokens,
            &[tokens.len() / 2, tokens.len()],
        );
        client.catalog.lock().unwrap().register(&ranges);
    }
    let r_fp = client.query(&p_fp).expect("fp");
    assert!(r_fp.false_positive);
    println!(
        "clean miss TTFT {:.2} ms vs forced-FP miss TTFT {:.2} ms (extra = wasted GET round trip)",
        r_clean.breakdown.ttft().as_secs_f64() * 1e3,
        r_fp.breakdown.ttft().as_secs_f64() * 1e3
    );
    println!("correctness preserved: FP query still produced {} tokens", r_fp.response_tokens.len());
    client.shutdown();
    cb.shutdown();
}
