//! Gossip acceptance bench — fleet-converged health under packet-level
//! chaos, the scripted harness for the PR 8 SWIM layer:
//!
//! * **(a) fleet-wide detection latency, gossip vs per-client ablation**: a
//!   3-client × 3-box fleet with deliberately staggered heartbeat cadences
//!   (one fast prober, two slow ones) loses a box for good.  With gossip,
//!   the fast client's first-hand `Dead` verdict rides the boxes' gossip
//!   blackboards and the slow clients adopt it on their *next* exchange —
//!   well before their own strike budgets could conclude anything.  The
//!   ablation runs the identical fleet with gossip off, so every client
//!   pays its own detection latency.  Asserted: gossiped detection is
//!   strictly faster for at least 2 of the 3 clients, fleet convergence is
//!   strictly faster, and neither run ever declares a live box `Dead`.
//! * **(b) asymmetric partition — refutation + indirect probes, zero false
//!   deaths**: a [`ChaosProxy`] cuts exactly one client↔box edge while
//!   every other path stays up.  The partitioned client's strike budget
//!   keeps exhausting, but each circumstantial verdict is withheld by a
//!   relay probe through a third box, the spreading suspicion is refuted by
//!   the subject's bumped incarnation on the gossip wire, and the hit rate
//!   through the partition stays 1.0 via head rotation.  Asserted: zero
//!   `Dead` transitions fleet-wide, ≥ 1 probe save, ≥ 1 wire refutation.
//! * **(c) byte-fault schedules end bit-exact**: seeded per-op byte faults
//!   (`TruncateAt` / `CorruptByteAt` / `ResetAfter`) damage chunk replies
//!   mid-stream; chunk crcs reject them, re-planning and the seeded local
//!   rescue ladder fill the orphans, and every restore is asserted
//!   bit-exact against the truth state.
//!
//! Emits `BENCH_gossip.json`.
//!
//! Env: EDGECACHE_SMOKE=1 (reduced sizes for the check.sh gate),
//!      EDGECACHE_GOSSIP_JSON (output path, default BENCH_gossip.json).

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use edgecache::coordinator::fabric::{fetch_prefix_multi, LocalRecompute, Peer, PeerConfig};
use edgecache::coordinator::{
    CacheBox, CatalogSync, DeadlineBudget, HealthPolicy, Membership, Outcome,
    PeerHealth, PeerPlanner, RelayProber,
};
use edgecache::kvstore::KvClient;
use edgecache::model::state::{Compression, KvState};
use edgecache::netsim::{ChaosProxy, Fault, FaultPlan, FaultWindow, LinkModel};
use edgecache::util::json::Json;
use edgecache::util::rng::Rng;

const HASH: &str = "bench-gossip";
const DIMS: (usize, usize, usize, usize) = (4, 128, 2, 32); // 2 KB/token
const CT: usize = 4;

fn budget() -> DeadlineBudget {
    DeadlineBudget::from_millis(300, 400)
}

fn bench_link() -> LinkModel {
    LinkModel {
        name: "lan-64m",
        goodput_bps: 8e6,
        rtt: Duration::from_millis(2),
        jitter_frac: 0.0,
    }
}

fn filled_state(total_rows: usize, seed: u64) -> KvState {
    let (l, s, kh, d) = DIMS;
    let mut st = KvState::zeroed(l, s, kh, d);
    st.n_tokens = total_rows;
    let mut rng = Rng::new(seed);
    for x in st.k.iter_mut().take(total_rows * 2 * kh * d * l) {
        *x = rng.f64() as f32;
    }
    for x in st.v.iter_mut().take(total_rows * 2 * kh * d * l) {
        *x = rng.f64() as f32 - 0.5;
    }
    st
}

fn ms(d: Duration) -> f64 {
    d.as_secs_f64() * 1e3
}

fn p95(samples: &[f64]) -> f64 {
    if samples.is_empty() {
        return 0.0;
    }
    let mut v = samples.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    v[((v.len() - 1) as f64 * 0.95).round() as usize]
}

fn wait_for(what: &str, timeout: Duration, mut cond: impl FnMut() -> bool) {
    let t0 = Instant::now();
    while !cond() {
        assert!(t0.elapsed() < timeout, "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(10));
    }
}

// ------------------------------------------------------------- probers --

/// One fleet client reduced to its membership plane: a heartbeat loop that
/// pings every box each round (sync-loop classification: any failure is a
/// circumstantial `HeartbeatMiss`, never a conclusive `IoDead`) and — when
/// gossip is on — exchanges membership digests over the same connection,
/// exactly what `CatalogSync::spawn_gossip` piggybacks on a real client.
struct ProbeClient {
    membership: Arc<Membership>,
    /// First instant this client saw `deadly` as `Dead`.
    detect: Arc<Mutex<Option<Instant>>>,
    /// A peer outside `deadly` was declared `Dead` — a false positive.
    false_death: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

fn spawn_probe_client(
    dials: Vec<String>,
    membership: Arc<Membership>,
    deadly: Option<usize>,
    interval: Duration,
    gossip: bool,
    stop: Arc<AtomicBool>,
) -> ProbeClient {
    let detect = Arc::new(Mutex::new(None));
    let false_death = Arc::new(AtomicBool::new(false));
    let (m, d, f) = (Arc::clone(&membership), Arc::clone(&detect), Arc::clone(&false_death));
    let handle = std::thread::spawn(move || {
        while !stop.load(Ordering::Acquire) {
            for (j, addr) in dials.iter().enumerate() {
                let outcome = match KvClient::connect(addr) {
                    Ok(mut c) => {
                        let _ = c.set_io_timeout(Some(Duration::from_millis(150)));
                        match c.ping() {
                            Ok(()) => {
                                if gossip {
                                    // best-effort, like the sync loop: an
                                    // old box answers with an error, not a
                                    // broken heartbeat
                                    let _ = CatalogSync::gossip_once(&mut c, &m);
                                }
                                Outcome::HeartbeatOk
                            }
                            Err(_) => Outcome::HeartbeatMiss,
                        }
                    }
                    Err(_) => Outcome::HeartbeatMiss,
                };
                m.report(j, outcome);
            }
            for j in 0..dials.len() {
                if m.state(j) != PeerHealth::Dead {
                    continue;
                }
                if deadly == Some(j) {
                    let mut slot = d.lock().unwrap();
                    if slot.is_none() {
                        *slot = Some(Instant::now());
                    }
                } else {
                    f.store(true, Ordering::Release);
                }
            }
            std::thread::sleep(interval);
        }
    });
    ProbeClient { membership, detect, false_death, handle: Some(handle) }
}

impl ProbeClient {
    fn join(&mut self) {
        if let Some(h) = self.handle.take() {
            h.join().expect("probe client join");
        }
    }
}

// ------------------------------------------- (a) detection vs ablation --

struct DetectOut {
    /// Per-client `Dead(victim)` detection latency from the kill instant.
    detect_ms: Vec<f64>,
    /// Fleet convergence: the slowest client's detection latency.
    converge_ms: f64,
    false_deaths: bool,
    adoptions: u64,
}

/// One detection run: 3 boxes, 3 membership-plane clients with staggered
/// cadences (client 0 fast, clients 1-2 slow), box 2 killed for good.
fn detection_run(gossip: bool, fast: Duration, slow: Duration) -> DetectOut {
    let victim = 2usize;
    let mut boxes: Vec<Option<CacheBox>> = (0..3)
        .map(|_| Some(CacheBox::start_local().expect("box start")))
        .collect();
    let addrs: Vec<String> = boxes.iter().map(|b| b.as_ref().unwrap().addr()).collect();

    let stop = Arc::new(AtomicBool::new(false));
    let mut clients: Vec<ProbeClient> = [fast, slow, slow]
        .iter()
        .map(|&iv| {
            spawn_probe_client(
                addrs.clone(),
                Membership::with_addrs(addrs.clone(), HealthPolicy::default()),
                Some(victim),
                iv,
                gossip,
                Arc::clone(&stop),
            )
        })
        .collect();

    // warm: every client must complete a few healthy rounds first
    std::thread::sleep(slow.max(Duration::from_millis(200)) + slow / 2);
    for c in &clients {
        assert_eq!(c.membership.state(victim), PeerHealth::Up, "warm fleet must be Up");
    }

    let t_kill = Instant::now();
    boxes[victim].take().expect("victim alive").shutdown();
    wait_for("fleet-wide death detection", Duration::from_secs(20), || {
        clients.iter().all(|c| c.detect.lock().unwrap().is_some())
    });
    stop.store(true, Ordering::Release);
    for c in &mut clients {
        c.join();
    }

    let detect_ms: Vec<f64> = clients
        .iter()
        .map(|c| ms(c.detect.lock().unwrap().expect("detected") - t_kill))
        .collect();
    let out = DetectOut {
        converge_ms: detect_ms.iter().cloned().fold(0.0, f64::max),
        false_deaths: clients.iter().any(|c| c.false_death.load(Ordering::Acquire)),
        adoptions: clients.iter().map(|c| c.membership.gossip_adoptions()).sum(),
        detect_ms,
    };
    for b in boxes.into_iter().flatten() {
        b.shutdown();
    }
    out
}

fn detection_section(smoke: bool, json: &mut Vec<(&'static str, Json)>) {
    // cadences are the experiment: the fast prober detects first-hand,
    // the slow probers can only beat their own strike budgets via gossip
    let (fast, slow) = if smoke {
        (Duration::from_millis(15), Duration::from_millis(250))
    } else {
        (Duration::from_millis(20), Duration::from_millis(500))
    };
    let g = detection_run(true, fast, slow);
    let a = detection_run(false, fast, slow);
    println!(
        "(a) detection latency (ms): gossip {:?} (converge {:.0}), \
         ablation {:?} (converge {:.0}), {} gossip adoptions",
        g.detect_ms.iter().map(|x| x.round()).collect::<Vec<_>>(),
        g.converge_ms,
        a.detect_ms.iter().map(|x| x.round()).collect::<Vec<_>>(),
        a.converge_ms,
        g.adoptions,
    );
    assert!(!g.false_deaths && !a.false_deaths, "no live box may be declared Dead");
    let faster = g
        .detect_ms
        .iter()
        .zip(&a.detect_ms)
        .filter(|(g, a)| g < a)
        .count();
    assert!(
        faster >= 2,
        "gossip must strictly beat per-client detection for >= 2 of 3 clients \
         (gossip {:?} vs ablation {:?})",
        g.detect_ms,
        a.detect_ms,
    );
    assert!(
        g.converge_ms < a.converge_ms,
        "fleet convergence must be strictly faster with gossip \
         ({:.0} ms vs {:.0} ms)",
        g.converge_ms,
        a.converge_ms,
    );
    assert!(g.adoptions >= 1, "the slow clients must have adopted the verdict");
    let arr = |v: &[f64]| Json::Arr(v.iter().map(|&x| Json::Num(x)).collect());
    json.push((
        "detection",
        Json::obj(vec![
            ("fast_interval_ms", Json::Int(fast.as_millis() as i64)),
            ("slow_interval_ms", Json::Int(slow.as_millis() as i64)),
            (
                "gossip",
                Json::obj(vec![
                    ("client_detect_ms", arr(&g.detect_ms)),
                    ("converge_ms", Json::Num(g.converge_ms)),
                    ("adoptions", Json::Int(g.adoptions as i64)),
                    ("false_deaths", Json::Int(0)),
                ]),
            ),
            (
                "ablation",
                Json::obj(vec![
                    ("client_detect_ms", arr(&a.detect_ms)),
                    ("converge_ms", Json::Num(a.converge_ms)),
                    ("false_deaths", Json::Int(0)),
                ]),
            ),
            ("clients_faster_with_gossip", Json::Int(faster as i64)),
        ]),
    ));
}

// --------------------------------------- (b) asymmetric partition -------

fn partition_section(smoke: bool, json: &mut Vec<(&'static str, Json)>) {
    let (rows, m) = (24usize, 16usize);
    let n_fetches = if smoke { 5 } else { 10 };
    let cb_a = CacheBox::start_local().expect("box a");
    let cb_b = CacheBox::start_local().expect("box b");
    let cb_v = CacheBox::start_local().expect("box v");
    let st = filled_state(rows, 505);
    let blob = st.serialize_prefix_opts(rows, HASH, Compression::None, CT);
    let truth = KvState::restore(
        &st.serialize_prefix_opts(m, HASH, Compression::None, CT),
        HASH,
        DIMS,
    )
    .expect("truth restore");
    for cb in [&cb_a, &cb_v] {
        KvClient::connect(&cb.addr())
            .expect("seed conn")
            .set(b"state:part", &blob)
            .expect("seed");
    }

    // the partitioned client P reaches box V only through the proxy; its
    // gossip identity stays the real box address so digests, relay probes
    // and the boxes' self-refutation all speak about the same peer
    let mut proxy = ChaosProxy::start(&cb_v.addr()).expect("proxy start");
    let idents = vec![cb_a.addr(), cb_b.addr(), cb_v.addr()];
    let p_dials = vec![cb_a.addr(), cb_b.addr(), proxy.addr().to_string()];
    let p_cfgs = vec![
        PeerConfig::new(cb_a.addr()).with_deadline(budget()),
        PeerConfig::new(cb_b.addr()).with_deadline(budget()),
        PeerConfig::new(proxy.addr().to_string())
            .with_deadline(budget())
            .with_gossip_addr(cb_v.addr()),
    ];
    let mp = Membership::with_addrs(idents.clone(), HealthPolicy::default());
    mp.set_prober(Arc::new(RelayProber::new(&p_cfgs, budget())), 2);
    let mq = Membership::with_addrs(idents.clone(), HealthPolicy::default());

    let stop = Arc::new(AtomicBool::new(false));
    let mut p = spawn_probe_client(
        p_dials,
        Arc::clone(&mp),
        None, // nobody is allowed to die in this scenario
        Duration::from_millis(60),
        true,
        Arc::clone(&stop),
    );
    let mut q = spawn_probe_client(
        idents,
        Arc::clone(&mq),
        None,
        Duration::from_millis(50),
        true,
        Arc::clone(&stop),
    );

    std::thread::sleep(Duration::from_millis(300));
    proxy.set_partitioned(true);

    // hit-rate retention through the dark edge: P's fetches prefer the
    // proxied box, rotate off the severed socket and restore from A.  The
    // fetch peers deliberately carry no health sink — the membership plane
    // is the heartbeat loop above, which classifies the partition
    // circumstantially; a conclusive hot-path reset through the proxy is
    // exactly the false verdict the probe/refutation layer is for.
    let planner = PeerPlanner::default();
    let mut pv = Peer::connect(p_cfgs[2].clone(), bench_link(), 61, 1).expect("peer v");
    let mut pa = Peer::connect(p_cfgs[0].clone(), bench_link(), 62, 1).expect("peer a");
    let mut lat = Vec::new();
    let mut hits = 0usize;
    for i in 0..n_fetches {
        let t0 = Instant::now();
        let f = {
            let mut cl = vec![(2usize, &mut pv), (0usize, &mut pa)];
            fetch_prefix_multi(
                &mut cl, &planner, b"state:part", rows, false, CT, m, HASH, DIMS, None,
            )
        }
        .unwrap_or_else(|| panic!("partitioned fetch {i} must restore via A"));
        lat.push(ms(t0.elapsed()));
        assert_eq!(f.state.k, truth.k, "partitioned fetch {i}: corrupt restore");
        assert_eq!(f.state.v, truth.v);
        hits += 1;
    }

    // the strike budget must keep exhausting and every circumstantial
    // verdict must be withheld by a relay that still reaches V
    wait_for("a probe save", Duration::from_secs(15), || mp.probe_saves() >= 1);
    // P's suspicion spreads through the blackboards; V hears it on the
    // clean client's exchange and refutes with a bumped incarnation, which
    // the clean client adopts as a *wire* refutation
    wait_for("a wire refutation", Duration::from_secs(15), || mq.refutations() >= 1);

    proxy.set_partitioned(false);
    wait_for("partition heal", Duration::from_secs(15), || {
        mp.state(2) == PeerHealth::Up
    });
    stop.store(true, Ordering::Release);
    p.join();
    q.join();

    let false_deaths = mp.deaths()
        + mq.deaths()
        + u64::from(p.false_death.load(Ordering::Acquire))
        + u64::from(q.false_death.load(Ordering::Acquire));
    println!(
        "(b) asymmetric partition: {hits}/{n_fetches} hits (p95 {:.2} ms), \
         {} probe saves / {} indirect probes, {} wire refutations, \
         incarnation {}, {} false deaths",
        p95(&lat),
        mp.probe_saves(),
        mp.indirect_probes(),
        mq.refutations(),
        mq.incarnation(2),
        false_deaths,
    );
    assert_eq!(false_deaths, 0, "an asymmetric partition must never kill a live box");
    assert_eq!(hits, n_fetches, "hit rate through the partition must stay 1.0");
    assert!(mp.probe_saves() >= 1 && mp.indirect_probes() >= 1);
    assert!(mq.refutations() >= 1, "the bumped incarnation must refute on the wire");
    assert!(mq.incarnation(2) >= 1, "refutation must have bumped V's incarnation");
    json.push((
        "partition",
        Json::obj(vec![
            ("fetches", Json::Int(n_fetches as i64)),
            ("hit_rate", Json::Num(hits as f64 / n_fetches as f64)),
            ("p95_ms", Json::Num(p95(&lat))),
            ("indirect_probes", Json::Int(mp.indirect_probes() as i64)),
            ("probe_saves", Json::Int(mp.probe_saves() as i64)),
            ("wire_refutations", Json::Int(mq.refutations() as i64)),
            ("victim_incarnation", Json::Int(mq.incarnation(2) as i64)),
            ("false_deaths", Json::Int(false_deaths as i64)),
        ]),
    ));
    proxy.shutdown();
    cb_a.shutdown();
    cb_b.shutdown();
    cb_v.shutdown();
}

// ------------------------------------------- (c) byte-fault schedules ---

/// A truth-backed recompute feeder (the bench stays engine-free): raw row
/// payloads straight from the full source state, exactly the
/// `StateAssembler::commit_chunk` contract.
fn truth_payloads(
    source: &KvState,
    total_rows: usize,
    chunks: &[usize],
) -> Option<Vec<(usize, Vec<u8>)>> {
    Some(
        chunks
            .iter()
            .map(|&c| {
                let real = CT.min(total_rows - c * CT);
                (c, source.chunk_payload(c * CT, real))
            })
            .collect(),
    )
}

fn byte_fault_section(smoke: bool, json: &mut Vec<(&'static str, Json)>) {
    let (rows, m) = (24usize, 16usize);
    let st = filled_state(rows, 909);
    let blob = st.serialize_prefix_opts(rows, HASH, Compression::None, CT);
    let truth = KvState::restore(
        &st.serialize_prefix_opts(m, HASH, Compression::None, CT),
        HASH,
        DIMS,
    )
    .expect("truth restore");
    let cb_1 = CacheBox::start_local().expect("box 1");
    let cb_2 = CacheBox::start_local().expect("box 2");
    for cb in [&cb_1, &cb_2] {
        KvClient::connect(&cb.addr())
            .expect("seed conn")
            .set(b"state:bytes", &blob)
            .expect("seed");
    }
    let planner = PeerPlanner::default();

    // -- (c1) mixed schedule against a clean partner ----------------------
    // every early op on peer 1 carries some byte fault; the clean partner
    // plus re-planning must keep each restore bit-exact
    let n_fetches = if smoke { 5u64 } else { 8 };
    let points: Vec<(u64, Fault)> = (0..n_fetches * 4)
        .map(|i| {
            let f = match i % 3 {
                0 => Fault::TruncateAt((i as usize * 7) % 97),
                1 => Fault::CorruptByteAt((i as usize * 13) % 127),
                _ => Fault::ResetAfter((i as usize * 11) % 83),
            };
            (i, f)
        })
        .collect();
    let mut p1 = Peer::connect(
        PeerConfig::new(cb_1.addr()).with_deadline(budget()),
        bench_link(),
        71,
        1,
    )
    .expect("peer 1");
    let mut p2 = Peer::connect(
        PeerConfig::new(cb_2.addr()).with_deadline(budget()),
        bench_link(),
        72,
        1,
    )
    .expect("peer 2");
    p1.shaper.attach_faults(FaultPlan::at_ops(&points));
    let (mut re_plans, mut share_failures, mut recomputed) = (0u64, 0u64, 0usize);
    let mut lat = Vec::new();
    for i in 0..n_fetches {
        let mut feed =
            |chunks: &[usize], _seed: Option<KvState>| truth_payloads(&st, rows, chunks);
        let lr = LocalRecompute { feed: &mut feed, prefill_ms_per_tok: 5.0 };
        let t0 = Instant::now();
        let f = {
            // alternate head preference so the faulted peer keeps serving
            let mut cl: Vec<(usize, &mut Peer)> = if i % 2 == 0 {
                vec![(0, &mut p1), (1, &mut p2)]
            } else {
                vec![(1, &mut p2), (0, &mut p1)]
            };
            fetch_prefix_multi(
                &mut cl, &planner, b"state:bytes", rows, false, CT, m, HASH, DIMS,
                Some(lr),
            )
        }
        .unwrap_or_else(|| panic!("chaos fetch {i} must still restore"));
        lat.push(ms(t0.elapsed()));
        assert_eq!(f.state.k, truth.k, "chaos fetch {i}: corrupt restore");
        assert_eq!(f.state.v, truth.v, "chaos fetch {i}: corrupt restore");
        re_plans += f.re_plans;
        share_failures += f.share_failures;
        recomputed += f.chunks_recomputed;
    }
    let faulted = p1.shaper.faulted_ops;
    assert!(faulted >= 1, "the byte-fault schedule must have fired");
    assert!(
        re_plans + share_failures + recomputed as u64 >= 1,
        "at least one damaged reply must have forced the rescue ladder"
    );
    println!(
        "(c1) mixed byte faults: {n_fetches} fetches, {faulted} faulted ops, \
         {share_failures} share failures, {re_plans} re-plans, \
         {recomputed} chunks recomputed, p95 {:.2} ms, all bit-exact",
        p95(&lat),
    );

    // -- (c2) every wire path damaged: the rescue ladder must finish ------
    // both peers corrupt the first chunk of every op's stream, so the wire
    // can never complete the prefix on its own; the fetch still succeeds
    // only because the (seed-aware) local rescue recomputes the orphans
    let mut r1 = Peer::connect(
        PeerConfig::new(cb_1.addr()).with_deadline(budget()),
        bench_link(),
        81,
        1,
    )
    .expect("rescue peer 1");
    let mut r2 = Peer::connect(
        PeerConfig::new(cb_2.addr()).with_deadline(budget()),
        bench_link(),
        82,
        1,
    )
    .expect("rescue peer 2");
    let everywhere = || {
        FaultPlan::new(vec![FaultWindow {
            from_op: 0,
            to_op: u64::MAX,
            fault: Fault::CorruptByteAt(0),
        }])
    };
    r1.shaper.attach_faults(everywhere());
    r2.shaper.attach_faults(everywhere());
    let rescue_fetches = if smoke { 2 } else { 3 };
    let mut rescue_recomputed = 0usize;
    let mut seeded_rescues = 0u64;
    for i in 0..rescue_fetches {
        let mut feed = |chunks: &[usize], seed: Option<KvState>| {
            if seed.is_some() {
                seeded_rescues += 1;
            }
            truth_payloads(&st, rows, chunks)
        };
        let lr = LocalRecompute { feed: &mut feed, prefill_ms_per_tok: 5.0 };
        let f = {
            let mut cl = vec![(0usize, &mut r1), (1usize, &mut r2)];
            fetch_prefix_multi(
                &mut cl, &planner, b"state:bytes", rows, false, CT, m, HASH, DIMS,
                Some(lr),
            )
        }
        .unwrap_or_else(|| panic!("rescue fetch {i} must recompute its way out"));
        assert_eq!(f.state.k, truth.k, "rescue fetch {i}: corrupt restore");
        assert_eq!(f.state.v, truth.v, "rescue fetch {i}: corrupt restore");
        assert!(
            f.chunks_recomputed >= 1,
            "rescue fetch {i}: a perpetually damaged wire must force recompute"
        );
        rescue_recomputed += f.chunks_recomputed;
    }
    println!(
        "(c2) perpetual corruption: {rescue_fetches} fetches, \
         {rescue_recomputed} chunks recomputed ({seeded_rescues} seeded), \
         all bit-exact",
    );
    json.push((
        "byte_faults",
        Json::obj(vec![
            (
                "mixed",
                Json::obj(vec![
                    ("fetches", Json::Int(n_fetches as i64)),
                    ("faulted_ops", Json::Int(faulted as i64)),
                    ("share_failures", Json::Int(share_failures as i64)),
                    ("re_plans", Json::Int(re_plans as i64)),
                    ("chunks_recomputed", Json::Int(recomputed as i64)),
                    ("p95_ms", Json::Num(p95(&lat))),
                    ("bit_exact", Json::Bool(true)),
                ]),
            ),
            (
                "rescue",
                Json::obj(vec![
                    ("fetches", Json::Int(rescue_fetches as i64)),
                    ("chunks_recomputed", Json::Int(rescue_recomputed as i64)),
                    ("seeded_rescues", Json::Int(seeded_rescues as i64)),
                    ("bit_exact", Json::Bool(true)),
                ]),
            ),
        ]),
    ));
    cb_1.shutdown();
    cb_2.shutdown();
}

fn main() {
    edgecache::util::logger::init_from_env();
    let smoke = std::env::var("EDGECACHE_SMOKE").as_deref() == Ok("1");
    println!("=================================================================");
    println!(
        " gossip — SWIM digests, refuted suspicion, byte-level chaos{}",
        if smoke { "  [smoke]" } else { "" }
    );
    println!("=================================================================");

    let mut sections: Vec<(&'static str, Json)> = vec![
        ("smoke", Json::Bool(smoke)),
        ("dims", Json::Str(format!("{DIMS:?}"))),
    ];
    detection_section(smoke, &mut sections);
    partition_section(smoke, &mut sections);
    byte_fault_section(smoke, &mut sections);

    let json = Json::obj(sections);
    let path = std::env::var("EDGECACHE_GOSSIP_JSON")
        .unwrap_or_else(|_| "BENCH_gossip.json".into());
    match std::fs::write(&path, json.to_pretty()) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => println!("\ncould not write {path}: {e}"),
    }
    println!("gossip done.");
}
