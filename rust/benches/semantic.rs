//! Semantic-tier bench — paraphrased workload, semantic matching ON vs
//! the `--no-semantic` ablation, over the *identical* seeded trace.
//!
//! Per domain, one base prompt seeds the cache, then V seeded paraphrase
//! variants (`workload::perturb`, synonym-bucket swaps at the configured
//! rate) are queried.  A variant whose instruction got perturbed is a
//! **total exact miss** — the exact tier recovers nothing — which is
//! precisely where nearest-sketch search plus token-prefix verification
//! re-enters the game.  Both arms run a fresh cache box + client so
//! nothing leaks between them.
//!
//! A third mini-arm replays an *exact* (rate-0) repeat trace with
//! semantic enabled and asserts zero semantic probes: an exact workload
//! must see zero semantic wire traffic (no-regression gate).
//!
//! Mechanics gates (every run, smoke included):
//!   * ablation arm reports zero semantic probes/hits;
//!   * exact arm reports zero semantic probes (never engages on hits);
//!   * on-arm probes ≥ hits + false probes, and hits ≥ 1;
//!   * strict reuse win: on-arm reused-query count and matched-token
//!     total both exceed the ablation's;
//!   * accounting closes: matched_on == matched_off + tokens_recovered
//!     (the semantic tier adds exactly its verified prefixes, nothing
//!     else shifts);
//!   * bit-exactness: every paraphrase query's response text is
//!     byte-identical across arms — reused state never changes output.
//!
//! Performance gate (full run only — smoke runs unpaced on the host
//! profile, where TTFT deltas are noise): mean paraphrase TTFT with
//! semantic on is strictly below the ablation's under the paced device.
//!
//! Emits `BENCH_semantic.json`.
//!
//! Env: EDGECACHE_SMOKE=1 (tiny sizes, host device, mechanics-only),
//!      EDGECACHE_PERTURB (per-word swap rate, default 0.3),
//!      EDGECACHE_SEMANTIC_DIST (Hamming budget, default 24),
//!      EDGECACHE_DEVICE (paced profile for the full run, default
//!      pi5-4gb), EDGECACHE_SEMANTIC_JSON (output path, default
//!      BENCH_semantic.json).

use std::sync::Arc;

use edgecache::coordinator::{CacheBox, EdgeClient, EdgeClientConfig};
use edgecache::devicemodel::DeviceProfile;
use edgecache::engine::Engine;
use edgecache::util::json::Json;
use edgecache::workload::perturb::Perturber;
use edgecache::workload::{Generator, Prompt};

const SEED: u64 = 42;

struct ArmResult {
    name: &'static str,
    queries: usize,
    /// Paraphrase queries only (seeds excluded from scoring).
    para_queries: usize,
    reused: usize,
    matched_tokens: u64,
    prompt_tokens: u64,
    ttft_ms: Vec<f64>,
    responses: Vec<String>,
    bytes_down: u64,
    sem_probes: u64,
    sem_hits: u64,
    sem_false: u64,
    sem_tokens: u64,
}

impl ArmResult {
    fn reuse_rate(&self) -> f64 {
        if self.para_queries == 0 {
            return 0.0;
        }
        self.reused as f64 / self.para_queries as f64
    }

    fn mean_ttft_ms(&self) -> f64 {
        if self.ttft_ms.is_empty() {
            return 0.0;
        }
        self.ttft_ms.iter().sum::<f64>() / self.ttft_ms.len() as f64
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("arm", Json::str(self.name)),
            ("queries", Json::Int(self.queries as i64)),
            ("paraphrase_queries", Json::Int(self.para_queries as i64)),
            ("reused", Json::Int(self.reused as i64)),
            ("reuse_rate", Json::Num(self.reuse_rate())),
            ("matched_tokens", Json::Int(self.matched_tokens as i64)),
            ("prompt_tokens", Json::Int(self.prompt_tokens as i64)),
            ("mean_ttft_ms", Json::Num(self.mean_ttft_ms())),
            ("bytes_down", Json::Int(self.bytes_down as i64)),
            (
                "semantic",
                Json::obj(vec![
                    ("probes", Json::Int(self.sem_probes as i64)),
                    ("hits", Json::Int(self.sem_hits as i64)),
                    ("false_probes", Json::Int(self.sem_false as i64)),
                    ("tokens_recovered", Json::Int(self.sem_tokens as i64)),
                ]),
            ),
        ])
    }
}

struct ArmSpec {
    name: &'static str,
    semantic: bool,
    /// Per-word synonym-swap probability for the paraphrase variants.
    rate: f64,
}

#[allow(clippy::too_many_arguments)]
fn run_arm(
    engine: &Arc<Engine>,
    spec: &ArmSpec,
    domains: &[&str],
    variants: usize,
    shots: usize,
    dist: u32,
    device: &DeviceProfile,
) -> ArmResult {
    let cb = CacheBox::start_local().expect("cache box");
    let mut cfg = EdgeClientConfig::native(Some(cb.addr()));
    cfg.max_new_tokens = Some(2);
    cfg.sync_interval = None;
    cfg.semantic = spec.semantic;
    cfg.semantic_dist = dist;
    cfg.device = device.clone();
    let mut client = EdgeClient::new(Arc::clone(engine), cfg).expect("client");

    let gen = Generator::new(SEED);
    let mut res = ArmResult {
        name: spec.name,
        queries: 0,
        para_queries: 0,
        reused: 0,
        matched_tokens: 0,
        prompt_tokens: 0,
        ttft_ms: Vec::new(),
        responses: Vec::new(),
        bytes_down: 0,
        sem_probes: 0,
        sem_hits: 0,
        sem_false: 0,
        sem_tokens: 0,
    };
    for (di, domain) in domains.iter().enumerate() {
        let base = gen.prompt(domain, di as u64, shots);
        let _ = client.query(&base).expect("seed query");
        res.queries += 1;
        for v in 0..variants {
            // per-variant stable paraphrase: the SAME text lands in every arm
            let mut pert =
                Perturber::new(SEED ^ ((di * 101 + v + 1) as u64), spec.rate);
            pert.reorder = 0.0;
            let p: Prompt = pert.perturb(&base);
            let r = client.query(&p).expect("paraphrase query");
            res.queries += 1;
            res.para_queries += 1;
            if r.matched_tokens > 0 {
                res.reused += 1;
            }
            res.matched_tokens += r.matched_tokens as u64;
            res.prompt_tokens += r.prompt_tokens as u64;
            res.ttft_ms.push(r.breakdown.ttft().as_secs_f64() * 1e3);
            res.responses.push(r.response_text);
        }
    }
    res.bytes_down = client.stats.bytes_down;
    res.sem_probes = client.stats.semantic_probes;
    res.sem_hits = client.stats.semantic_hits;
    res.sem_false = client.stats.semantic_false_probes;
    res.sem_tokens = client.stats.semantic_tokens_recovered;
    client.shutdown();
    cb.shutdown();
    res
}

fn main() {
    edgecache::util::logger::init_from_env();
    let smoke = std::env::var("EDGECACHE_SMOKE").as_deref() == Ok("1");
    let rate: f64 = std::env::var("EDGECACHE_PERTURB")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0.3);
    let dist: u32 = std::env::var("EDGECACHE_SEMANTIC_DIST")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(24);
    // smoke runs unpaced (host): mechanics only, wall-clock bounded.  The
    // full run paces prefill on a real profile so recovered tokens show up
    // as real TTFT milliseconds.
    let device = if smoke {
        DeviceProfile::host()
    } else {
        let name = std::env::var("EDGECACHE_DEVICE").unwrap_or_default();
        DeviceProfile::by_name(&name).unwrap_or_else(DeviceProfile::pi5_4gb)
    };
    let (domains, variants, shots): (&[&str], usize, usize) = if smoke {
        (&["astronomy", "marketing"], 5, 1)
    } else {
        (&["astronomy", "marketing", "virology"], 10, 2)
    };

    println!("================================================================");
    println!(" Semantic tier — paraphrased workload, on vs --no-semantic");
    println!("================================================================");
    println!(
        "rate {rate}, dist {dist}, device {}, {} domains x {} variants ({}-shot){}",
        device.name,
        domains.len(),
        variants,
        shots,
        if smoke { "  [smoke]" } else { "" }
    );
    assert!(rate >= 0.1, "perturbation rate below the acceptance floor");

    let engine = match Engine::load_preset("tiny") {
        Ok(e) => Arc::new(e),
        Err(e) => {
            println!("skipping: tiny preset unavailable ({e})");
            return;
        }
    };

    let on = run_arm(
        &engine,
        &ArmSpec { name: "semantic", semantic: true, rate },
        domains,
        variants,
        shots,
        dist,
        &device,
    );
    let off = run_arm(
        &engine,
        &ArmSpec { name: "no-semantic", semantic: false, rate },
        domains,
        variants,
        shots,
        dist,
        &device,
    );
    // exact-repeat trace (rate 0 = every variant is the base prompt):
    // semantic stays enabled but must never engage
    let exact = run_arm(
        &engine,
        &ArmSpec { name: "exact", semantic: true, rate: 0.0 },
        &domains[..1],
        2.min(variants),
        shots,
        dist,
        &device,
    );

    for a in [&on, &off, &exact] {
        println!(
            "{:>12}: {}/{} paraphrase queries reused, {} matched tokens, \
             mean TTFT {:.2} ms, semantic {} probes / {} hits / {} false / {} tokens",
            a.name,
            a.reused,
            a.para_queries,
            a.matched_tokens,
            a.mean_ttft_ms(),
            a.sem_probes,
            a.sem_hits,
            a.sem_false,
            a.sem_tokens
        );
    }

    // -- mechanics gates (every run, smoke included) ----------------------
    assert_eq!(off.sem_probes, 0, "ablation arm sent semantic probes");
    assert_eq!(off.sem_hits, 0, "ablation arm recorded semantic hits");
    assert_eq!(
        exact.sem_probes, 0,
        "semantic engaged on an exact-repeat workload (must only fire on total misses)"
    );
    assert_eq!(exact.reused, exact.para_queries, "exact repeats must all hit");
    assert!(on.sem_hits >= 1, "paraphrased trace produced no semantic hits");
    assert!(
        on.sem_probes >= on.sem_hits + on.sem_false,
        "probe ledger does not cover hits + false probes"
    );
    assert!(
        on.reused > off.reused,
        "semantic did not strictly improve reuse: {} vs {}",
        on.reused,
        off.reused
    );
    assert!(
        on.matched_tokens > off.matched_tokens,
        "semantic did not strictly improve matched tokens"
    );
    assert_eq!(
        on.matched_tokens,
        off.matched_tokens + on.sem_tokens,
        "accounting drift: semantic must add exactly its verified prefixes"
    );
    assert_eq!(on.responses, off.responses, "reused state changed a response");

    // -- performance gate (full run only: unpaced smoke TTFT is noise) ----
    if !smoke {
        assert!(
            on.mean_ttft_ms() < off.mean_ttft_ms(),
            "semantic mean TTFT {:.2} ms not strictly under ablation {:.2} ms",
            on.mean_ttft_ms(),
            off.mean_ttft_ms()
        );
    }

    let json = Json::obj(vec![
        ("bench", Json::str("semantic")),
        ("smoke", Json::Bool(smoke)),
        ("rate", Json::Num(rate)),
        ("semantic_dist", Json::Int(dist as i64)),
        ("device", Json::str(device.name)),
        ("domains", Json::Int(domains.len() as i64)),
        ("variants", Json::Int(variants as i64)),
        ("shots", Json::Int(shots as i64)),
        (
            "arms",
            Json::Arr(vec![on.to_json(), off.to_json(), exact.to_json()]),
        ),
        (
            "verdict",
            Json::obj(vec![
                ("reuse_gain", Json::Num(on.reuse_rate() - off.reuse_rate())),
                (
                    "ttft_delta_ms",
                    Json::Num(off.mean_ttft_ms() - on.mean_ttft_ms()),
                ),
                (
                    "tokens_recovered",
                    Json::Int(on.sem_tokens as i64),
                ),
                ("false_probes", Json::Int(on.sem_false as i64)),
            ]),
        ),
    ]);
    let path = std::env::var("EDGECACHE_SEMANTIC_JSON")
        .unwrap_or_else(|_| "BENCH_semantic.json".into());
    match std::fs::write(&path, json.to_pretty()) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => println!("could not write {path}: {e}"),
    }
    println!("OK");
}
