//! Peer-fabric acceptance bench — the PR's two headline claims, measured
//! over real cache-box TCP servers with modelled links in between:
//!
//! * **(a) multi-source beats single-source**: the same partial hit fetched
//!   from one box vs striped across two boxes.  Each peer's modelled wire
//!   time elapses in its own thread, so the two-peer fetch approaches half
//!   the shaped TTFT (transfer dominates at these sizes) — asserted
//!   strictly, per iteration-minimum.
//! * **(b) hit-rate retention through a mid-trace peer death**: a trace of
//!   partial-hit fetches against two replicated boxes; one box is killed
//!   halfway.  Every remaining fetch must still complete bit-exact via the
//!   survivor (head rotation + orphan re-planning), keeping the hit rate
//!   at 1.0 — also asserted.
//!
//! Emits `BENCH_peer_fabric.json`.
//!
//! Env: EDGECACHE_SMOKE=1 (reduced sizes for the check.sh gate),
//!      EDGECACHE_PEER_FABRIC_JSON (output path, default
//!      BENCH_peer_fabric.json).

use std::time::{Duration, Instant};

use edgecache::coordinator::fabric::{fetch_prefix_multi, Peer, PeerConfig};
use edgecache::coordinator::{CacheBox, PeerPlanner};
use edgecache::kvstore::KvClient;
use edgecache::model::state::{Compression, KvState};
use edgecache::netsim::LinkModel;
use edgecache::util::json::Json;
use edgecache::util::rng::Rng;

const HASH: &str = "bench-fabric";
const DIMS: (usize, usize, usize, usize) = (8, 256, 2, 64); // 16 KB/token

fn filled_state(total_rows: usize, seed: u64) -> KvState {
    let (l, s, kh, d) = DIMS;
    let mut st = KvState::zeroed(l, s, kh, d);
    st.n_tokens = total_rows;
    let mut rng = Rng::new(seed);
    for x in st.k.iter_mut().take(total_rows * 2 * kh * d * l) {
        *x = rng.f64() as f32;
    }
    for x in st.v.iter_mut().take(total_rows * 2 * kh * d * l) {
        *x = rng.f64() as f32 - 0.5;
    }
    st
}

fn bench_link() -> LinkModel {
    LinkModel {
        name: "lan-64m",
        goodput_bps: 8e6, // 8 MB/s: transfer dominates, stripes pay off
        rtt: Duration::from_millis(2),
        jitter_frac: 0.0,
    }
}

fn peer_for(addr: &str, seed: u64) -> Peer {
    Peer::connect(PeerConfig::new(addr), bench_link(), seed, 1)
        .expect("peer connect")
}

fn ms(d: Duration) -> f64 {
    d.as_secs_f64() * 1e3
}

fn main() {
    edgecache::util::logger::init_from_env();
    let smoke = std::env::var("EDGECACHE_SMOKE").as_deref() == Ok("1");
    let planner = PeerPlanner::default();
    let ct = 4usize;

    println!("=================================================================");
    println!(" peer fabric — multi-source fetch + mid-trace peer death{}",
        if smoke { "  [smoke]" } else { "" });
    println!("=================================================================");

    // ---- (a) 1-peer vs 2-peer shaped fetch latency ----------------------
    let (total, m) = if smoke { (32usize, 24usize) } else { (64usize, 48usize) };
    let iters = if smoke { 2 } else { 3 };
    let st = filled_state(total, 7);
    // uncompressed: deterministic byte volume, so the comparison is pure
    // link scheduling (striping), not codec luck
    let blob = st.serialize_prefix_opts(total, HASH, Compression::None, ct);
    let truth = KvState::restore(
        &st.serialize_prefix_opts(m, HASH, Compression::None, ct),
        HASH,
        DIMS,
    )
    .expect("truth restore");

    let cb_a = CacheBox::start_local().expect("box a");
    let cb_b = CacheBox::start_local().expect("box b");
    for cb in [&cb_a, &cb_b] {
        let mut c = KvClient::connect(&cb.addr()).expect("seed conn");
        c.set(b"state:a", &blob).expect("seed");
    }

    let mut pa = peer_for(&cb_a.addr(), 1);
    let mut pb = peer_for(&cb_b.addr(), 2);
    let mut single_min = Duration::MAX;
    let mut dual_min = Duration::MAX;
    let mut wire = 0usize;
    for _ in 0..iters {
        let t0 = Instant::now();
        let f = {
            let mut claimers = vec![(0usize, &mut pa)];
            fetch_prefix_multi(
                &mut claimers, &planner, b"state:a", total, false, ct, m, HASH, DIMS, None,
            )
            .expect("single fetch")
        };
        single_min = single_min.min(t0.elapsed());
        assert_eq!(f.state.k, truth.k, "single-source restore must be exact");

        let t0 = Instant::now();
        let f = {
            let mut claimers = vec![(0usize, &mut pa), (1usize, &mut pb)];
            fetch_prefix_multi(
                &mut claimers, &planner, b"state:a", total, false, ct, m, HASH, DIMS, None,
            )
            .expect("dual fetch")
        };
        dual_min = dual_min.min(t0.elapsed());
        wire = f.wire;
        assert!(f.multi_source, "two claimers must stripe");
        assert_eq!(f.re_plans, 0);
        assert_eq!(f.state.k, truth.k, "multi-source restore must be exact");
        assert_eq!(f.state.v, truth.v);
    }
    let speedup = single_min.as_secs_f64() / dual_min.as_secs_f64();
    println!(
        "(a) {}-row prefix of {} rows, {:.1} KB wire on {}: 1-peer {:>7.2} ms,  2-peer {:>7.2} ms  ({speedup:.2}x)",
        m,
        total,
        wire as f64 / 1e3,
        bench_link().name,
        ms(single_min),
        ms(dual_min),
    );
    assert!(
        dual_min < single_min,
        "2-peer multi-source fetch ({dual_min:?}) must strictly beat 1-peer ({single_min:?})"
    );

    // ---- (b) mid-trace peer death: hit-rate retention -------------------
    let n_entries = if smoke { 2usize } else { 4usize };
    let n_fetches = if smoke { 6usize } else { 12usize };
    let (btotal, bm) = if smoke { (24usize, 16usize) } else { (32usize, 24usize) };
    let cb_c = CacheBox::start_local().expect("box c");
    let cb_d = CacheBox::start_local().expect("box d");
    let mut truths = Vec::new();
    for e in 0..n_entries {
        let st = filled_state(btotal, 100 + e as u64);
        // deflate here: the trace also exercises compressed striping
        let blob = st.serialize_prefix_opts(btotal, HASH, Compression::Deflate, ct);
        for cb in [&cb_c, &cb_d] {
            let mut c = KvClient::connect(&cb.addr()).expect("seed conn");
            c.set(format!("state:t{e}").as_bytes(), &blob).expect("seed");
        }
        truths.push(
            KvState::restore(
                &st.serialize_prefix_opts(bm, HASH, Compression::Deflate, ct),
                HASH,
                DIMS,
            )
            .expect("truth restore"),
        );
    }
    let mut pc = peer_for(&cb_c.addr(), 3);
    let mut pd = peer_for(&cb_d.addr(), 4);
    let kill_at = n_fetches / 2;
    let mut cb_d = Some(cb_d);
    let (mut hits_before, mut hits_after) = (0usize, 0usize);
    let (mut replans, mut failures) = (0u64, 0u64);
    for i in 0..n_fetches {
        if i == kill_at {
            // peer D dies mid-trace; the catalogs still claim it
            cb_d.take().expect("box d alive").shutdown();
            println!("(b) fetch {i}: peer D killed");
        }
        let e = i % n_entries;
        let key = format!("state:t{e}");
        let f = {
            // alternate the claimer order so the dead peer also shows up
            // as the would-be head and exercises rotation
            let mut claimers: Vec<(usize, &mut Peer)> = if i % 2 == 0 {
                vec![(0, &mut pc), (1, &mut pd)]
            } else {
                vec![(1, &mut pd), (0, &mut pc)]
            };
            fetch_prefix_multi(
                &mut claimers, &planner, key.as_bytes(), btotal, true, ct, bm, HASH, DIMS, None,
            )
        };
        let f = f.unwrap_or_else(|| {
            panic!("fetch {i} must complete via the surviving peer")
        });
        assert_eq!(f.state.k, truths[e].k, "fetch {i}: corrupt restore");
        replans += f.re_plans;
        failures += f.share_failures;
        if i < kill_at {
            hits_before += 1;
        } else {
            hits_after += 1;
        }
    }
    let rate_before = hits_before as f64 / kill_at as f64;
    let rate_after = hits_after as f64 / (n_fetches - kill_at) as f64;
    println!(
        "(b) {n_fetches} fetches over {n_entries} replicated entries: hit rate {rate_before:.2} before death, {rate_after:.2} after ({replans} re-plans, {failures} share failures)"
    );
    assert_eq!(rate_after, 1.0, "survivor re-planning must retain every hit");
    assert!(
        replans >= 1 || failures >= 1,
        "the dead peer must have been planned around at least once"
    );

    let json = Json::obj(vec![
        ("smoke", Json::Bool(smoke)),
        ("dims", Json::Str(format!("{DIMS:?}"))),
        (
            "multi_source",
            Json::obj(vec![
                ("link", Json::Str(bench_link().name.to_string())),
                ("entry_rows", Json::Int(total as i64)),
                ("matched_rows", Json::Int(m as i64)),
                ("wire_bytes", Json::Int(wire as i64)),
                ("single_peer_ms", Json::Num(ms(single_min))),
                ("two_peer_ms", Json::Num(ms(dual_min))),
                ("speedup_x", Json::Num(speedup)),
            ]),
        ),
        (
            "peer_death",
            Json::obj(vec![
                ("entries", Json::Int(n_entries as i64)),
                ("fetches", Json::Int(n_fetches as i64)),
                ("killed_at", Json::Int(kill_at as i64)),
                ("hit_rate_before", Json::Num(rate_before)),
                ("hit_rate_after", Json::Num(rate_after)),
                ("re_plans", Json::Int(replans as i64)),
                ("share_failures", Json::Int(failures as i64)),
            ]),
        ),
    ]);
    let path = std::env::var("EDGECACHE_PEER_FABRIC_JSON")
        .unwrap_or_else(|_| "BENCH_peer_fabric.json".into());
    match std::fs::write(&path, json.to_pretty()) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => println!("\ncould not write {path}: {e}"),
    }
    cb_a.shutdown();
    cb_b.shutdown();
    cb_c.shutdown();
    println!("peer_fabric done.");
}
