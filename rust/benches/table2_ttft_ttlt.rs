//! Regenerates **Table 2 + Figure 4**: TTFT and TTLT on the low-end and
//! high-end settings under Case 1 (cache miss) vs Case 5 (full hit).
//!
//! Two tracks (DESIGN.md §6):
//!  * analytic — calibrated device/link models over the full 6434-prompt
//!    population (paper scale; absolute numbers land on the paper's);
//!  * real — the full stack (PJRT model, real sockets) on the `tiny` preset,
//!    natively and, when `EDGECACHE_PACED=1`, device-paced on a small sample
//!    (each paced low-end query costs ~24 s of wall clock).
//!
//! Env: EDGECACHE_BENCH_PROMPTS (default 6434), EDGECACHE_REAL_PROMPTS (4),
//!      EDGECACHE_PACED (off).

use std::sync::Arc;

use edgecache::engine::Engine;
use edgecache::report::experiments as exp;
use edgecache::report::{ascii_bars, pct_change};

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() {
    edgecache::util::logger::init_from_env();
    let n = env_usize("EDGECACHE_BENCH_PROMPTS", 6434);
    let n_real = env_usize("EDGECACHE_REAL_PROMPTS", 4);
    let seed = 42;

    println!("================================================================");
    println!(" Table 2 + Figure 4 — TTFT/TTLT, Case 1 (miss) vs Case 5 (hit)");
    println!("================================================================");

    println!("\n--- analytic track ({n} prompts/setting; paper ran 6434) ---\n");
    let mut headline = Vec::new();
    for s in [exp::Setting::low_end_paper(), exp::Setting::high_end_paper()] {
        let (miss, hit) = exp::analytic_table23(&s, seed, n);
        let (table, m) = exp::render_table2(s.name, &miss, &hit);
        println!("{table}");
        println!(
            "{}",
            ascii_bars(
                &format!("Figure 4 — {} [s]", s.name),
                &[
                    ("TTFT case1".into(), m[0]),
                    ("TTFT case5".into(), m[1]),
                    ("TTLT case1".into(), m[2]),
                    ("TTLT case5".into(), m[3]),
                ],
                "s",
            )
        );
        headline.push((s.name, pct_change(m[1], m[0]), pct_change(m[3], m[2])));
    }
    println!("paper:    Low-end  TTFT −93.12 %   TTLT −50.07 %");
    println!("paper:    High-end TTFT +7.08 %    TTLT +7.10 %");
    for (name, dttft, dttlt) in &headline {
        println!("measured: {name:<8} TTFT {dttft:+.2} %   TTLT {dttlt:+.2} %");
    }

    println!("\n--- real track (tiny preset, native speed, {n_real} prompts) ---\n");
    match Engine::load_preset("tiny") {
        Ok(engine) => {
            let engine = Arc::new(engine);
            let cfg = exp::RealRunCfg::native_tiny(n_real);
            match exp::real_table23(Arc::clone(&engine), &cfg) {
                Ok((miss, hit)) => {
                    let (table, m) = exp::render_table2("tiny/native", &miss, &hit);
                    println!("{table}");
                    println!(
                        "real-stack TTFT change on full hit: {:+.1} % (shape check: \
                         negative = cache wins even without pacing)",
                        pct_change(m[1], m[0])
                    );
                }
                Err(e) => println!("real track failed: {e}"),
            }

            if std::env::var("EDGECACHE_PACED").is_ok() {
                println!("\n--- real track, device-paced (low-end, 1 prompt) ---\n");
                let mut cfg = exp::RealRunCfg::native_tiny(1);
                cfg.paced = true;
                cfg.setting = exp::Setting::low_end_paper();
                match exp::real_table23(engine, &cfg) {
                    Ok((miss, hit)) => {
                        let (table, _) = exp::render_table2("low-end/paced", &miss, &hit);
                        println!("{table}");
                    }
                    Err(e) => println!("paced run failed: {e}"),
                }
            }
        }
        Err(e) => println!("skipping real track (artifacts missing?): {e}"),
    }
}
