"""AOT artifact schema and round-trip checks (the rust runtime's contract)."""

import json
import os

import numpy as np
import pytest

from compile import aot, model as M


@pytest.fixture(scope="module")
def exported(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    meta = aot.export_preset(M.PRESETS["tiny"], str(out))
    return str(out / "tiny"), meta


def test_meta_schema(exported):
    out_dir, meta = exported
    with open(os.path.join(out_dir, "meta.json")) as f:
        on_disk = json.load(f)
    assert on_disk == meta
    assert meta["format_version"] == 1
    assert meta["model_hash"] == M.PRESETS["tiny"].model_hash()
    names = [e["name"] for e in meta["entries"]]
    assert names[0] == "decode"
    for c in M.PRESETS["tiny"].prefill_chunks:
        assert f"prefill_{c}" in names


def test_params_bin_matches_manifest(exported):
    out_dir, meta = exported
    size = os.path.getsize(os.path.join(out_dir, "params.bin"))
    total = sum(p["size_bytes"] for p in meta["params"])
    assert size == total
    # offsets are contiguous and ordered
    off = 0
    for p in meta["params"]:
        assert p["offset_bytes"] == off
        assert p["size_bytes"] == 4 * int(np.prod(p["shape"])) if p["shape"] else 4
        off += p["size_bytes"]
    # manifest order == sorted name order (the jax pytree flatten contract)
    names = [p["name"] for p in meta["params"]]
    assert names == sorted(names)
    # total param count matches the config's closed form
    n_params = sum(int(np.prod(p["shape"] or [1])) for p in meta["params"])
    assert n_params == M.PRESETS["tiny"].n_params


def test_params_bin_reproducible(exported):
    out_dir, meta = exported
    with open(os.path.join(out_dir, "params.bin"), "rb") as f:
        blob = f.read()
    params = M.init_params(M.PRESETS["tiny"])
    for p in meta["params"]:
        want = np.asarray(params[p["name"]], dtype="<f4").tobytes()
        got = blob[p["offset_bytes"] : p["offset_bytes"] + p["size_bytes"]]
        assert got == want, p["name"]


def test_hlo_text_parseable(exported):
    out_dir, meta = exported
    for e in meta["entries"]:
        path = os.path.join(out_dir, e["hlo"])
        with open(path) as f:
            text = f.read()
        assert "ENTRY" in text, e["name"]
        assert "HloModule" in text
        # input arity recorded in meta matches the HLO entry params
        n_inputs = len(e["inputs"])
        assert text.count("parameter(") >= n_inputs


def test_entry_io_shapes(exported):
    _, meta = exported
    cfg = M.PRESETS["tiny"]
    for e in meta["entries"]:
        outs = {o["name"]: o for o in e["outputs"]}
        assert outs["kcache"]["shape"] == list(M.kv_cache_shape(cfg))
        if e["name"] == "decode":
            assert outs["logits"]["shape"] == [cfg.vocab]
        else:
            assert outs["logits"]["shape"] == [e["chunk"], cfg.vocab]
        roles = [i["role"] for i in e["inputs"]]
        assert roles.count("param") == len(M.PARAM_ORDER)
        assert "kv" in roles and "pos" in roles


def test_kv_bytes_per_token_matches_cache_shape():
    for cfg in M.PRESETS.values():
        l, s, kh, d = M.kv_cache_shape(cfg)
        assert cfg.kv_bytes_per_token == 2 * l * kh * d * 4
