"""L1 correctness: every Pallas kernel vs its pure-jnp oracle in ref.py.

Hypothesis sweeps shapes and dtypes; assert_allclose is the contract.  These
tests are the build-time gate for the AOT artifacts: if they pass, the HLO the
rust runtime executes computes the same numbers as the oracle.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import attention as attn_k
from compile.kernels import common
from compile.kernels import geglu as geglu_k
from compile.kernels import ref
from compile.kernels import rmsnorm as rms_k

SETTINGS = dict(max_examples=25, deadline=None)


def rnd(rng, shape, dtype, scale=1.0):
    x = rng.standard_normal(shape).astype(np.float32) * scale
    return jnp.asarray(x).astype(dtype)


def tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=2e-4, atol=2e-4)


def causal_mask(c, s, pos):
    rows = np.arange(c)[:, None]
    cols = np.arange(s)[None, :]
    return jnp.asarray(np.where(cols <= pos + rows, 0.0, ref.NEG_INF).astype(np.float32))


# ---------------------------------------------------------------------------
# pick_block
# ---------------------------------------------------------------------------


@given(n=st.integers(1, 4096), target=st.integers(1, 512))
@settings(max_examples=200, deadline=None)
def test_pick_block_divides(n, target):
    b = common.pick_block(n, target)
    assert 1 <= b <= min(n, target)
    assert n % b == 0


def test_pick_block_rejects_nonpositive():
    with pytest.raises(ValueError):
        common.pick_block(0, 8)


# ---------------------------------------------------------------------------
# rmsnorm
# ---------------------------------------------------------------------------


@given(
    n=st.integers(1, 64),
    d=st.sampled_from([8, 16, 64, 80, 128, 320]),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
    seed=st.integers(0, 2**31 - 1),
)
@settings(**SETTINGS)
def test_rmsnorm_matches_ref(n, d, dtype, seed):
    rng = np.random.default_rng(seed)
    x = rnd(rng, (n, d), dtype)
    w = rnd(rng, (d,), dtype, scale=0.1)
    got = rms_k.rmsnorm(x, w)
    want = ref.rmsnorm(x, w)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **tol(dtype)
    )


def test_rmsnorm_unit_scale_invariance():
    """RMSNorm output has unit RMS when w == 0 (Gemma gain = 1+0)."""
    rng = np.random.default_rng(0)
    x = rnd(rng, (16, 64), jnp.float32, scale=7.0)
    out = np.asarray(rms_k.rmsnorm(x, jnp.zeros(64)))
    rms = np.sqrt((out * out).mean(axis=-1))
    np.testing.assert_allclose(rms, np.ones(16), rtol=1e-4)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


@given(
    c=st.sampled_from([1, 3, 8, 16]),
    s=st.sampled_from([16, 64, 96, 128]),
    h_kh=st.sampled_from([(1, 1), (2, 1), (4, 2), (4, 4), (8, 2)]),
    d=st.sampled_from([8, 16, 32, 80]),
    pos=st.integers(0, 48),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
    seed=st.integers(0, 2**31 - 1),
)
@settings(**SETTINGS)
def test_prefill_attention_matches_ref(c, s, h_kh, d, pos, dtype, seed):
    h, kh = h_kh
    pos = min(pos, s - c) if s - c > 0 else 0
    rng = np.random.default_rng(seed)
    q = rnd(rng, (c, h, d), dtype)
    k = rnd(rng, (s, kh, d), dtype)
    v = rnd(rng, (s, kh, d), dtype)
    mask = causal_mask(c, s, pos)
    scale = 1.0 / np.sqrt(d)
    got = attn_k.prefill_attention(q, k, v, mask, scale)
    want = ref.prefill_attention(q, k, v, mask, scale)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **tol(dtype)
    )


@given(
    s=st.sampled_from([16, 64, 256]),
    h_kh=st.sampled_from([(1, 1), (4, 1), (4, 2), (8, 4)]),
    d=st.sampled_from([16, 64, 80]),
    n_valid=st.integers(1, 256),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
    seed=st.integers(0, 2**31 - 1),
)
@settings(**SETTINGS)
def test_decode_attention_matches_ref(s, h_kh, d, n_valid, dtype, seed):
    h, kh = h_kh
    n_valid = min(n_valid, s)
    rng = np.random.default_rng(seed)
    q = rnd(rng, (h, d), dtype)
    k = rnd(rng, (s, kh, d), dtype)
    v = rnd(rng, (s, kh, d), dtype)
    mask = jnp.asarray(
        np.where(np.arange(s) < n_valid, 0.0, ref.NEG_INF).astype(np.float32)
    )
    scale = 1.0 / np.sqrt(d)
    got = attn_k.decode_attention(q, k, v, mask, scale)
    want = ref.decode_attention(q, k, v, mask, scale)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **tol(dtype)
    )


def test_attention_masked_positions_have_no_influence():
    """Changing K/V beyond the mask must not change the output at all."""
    rng = np.random.default_rng(7)
    s, h, kh, d = 64, 4, 2, 16
    q = rnd(rng, (h, d), jnp.float32)
    k = np.asarray(rnd(rng, (s, kh, d), jnp.float32))
    v = np.asarray(rnd(rng, (s, kh, d), jnp.float32))
    n_valid = 20
    mask = jnp.asarray(
        np.where(np.arange(s) < n_valid, 0.0, ref.NEG_INF).astype(np.float32)
    )
    out1 = attn_k.decode_attention(q, jnp.asarray(k), jnp.asarray(v), mask, 0.25)
    k2, v2 = k.copy(), v.copy()
    k2[n_valid:] = 1e3
    v2[n_valid:] = -1e3
    out2 = attn_k.decode_attention(q, jnp.asarray(k2), jnp.asarray(v2), mask, 0.25)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))


def test_decode_equals_prefill_row():
    """decode_attention == the corresponding single row of prefill_attention."""
    rng = np.random.default_rng(3)
    c, s, h, kh, d = 4, 32, 4, 2, 16
    q = rnd(rng, (c, h, d), jnp.float32)
    k = rnd(rng, (s, kh, d), jnp.float32)
    v = rnd(rng, (s, kh, d), jnp.float32)
    mask = causal_mask(c, s, 8)
    full = attn_k.prefill_attention(q, k, v, mask, 0.25)
    for r in range(c):
        row = attn_k.decode_attention(q[r], k, v, mask[r], 0.25)
        np.testing.assert_allclose(
            np.asarray(row), np.asarray(full[r]), rtol=1e-5, atol=1e-5
        )


# ---------------------------------------------------------------------------
# geglu
# ---------------------------------------------------------------------------


@given(
    n=st.integers(1, 48),
    dm=st.sampled_from([16, 64, 320]),
    ff=st.sampled_from([32, 128, 256, 1280]),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
    seed=st.integers(0, 2**31 - 1),
)
@settings(**SETTINGS)
def test_geglu_matches_ref(n, dm, ff, dtype, seed):
    rng = np.random.default_rng(seed)
    x = rnd(rng, (n, dm), dtype)
    wg = rnd(rng, (dm, ff), dtype, scale=1 / np.sqrt(dm))
    wu = rnd(rng, (dm, ff), dtype, scale=1 / np.sqrt(dm))
    wd = rnd(rng, (ff, dm), dtype, scale=1 / np.sqrt(ff))
    got = geglu_k.geglu_ffn(x, wg, wu, wd)
    want = ref.geglu_ffn(x, wg, wu, wd)
    t = dict(rtol=5e-2, atol=5e-2) if dtype == jnp.bfloat16 else dict(rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **t
    )


def test_gelu_reference_values():
    """tanh-GELU at a few known points (sanity anchor for both impls)."""
    x = jnp.asarray([0.0, 1.0, -1.0, 3.0])
    got = np.asarray(ref.gelu(x))
    np.testing.assert_allclose(got[0], 0.0, atol=1e-7)
    np.testing.assert_allclose(got[1], 0.841192, rtol=1e-4)
    np.testing.assert_allclose(got[2], -0.158808, rtol=1e-3)
    np.testing.assert_allclose(got[3], 2.996363, rtol=1e-4)
