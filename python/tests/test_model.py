"""L2 correctness: the Gemma-like model's prefill/decode semantics.

The invariants here are exactly what the rust engine depends on:
  * Pallas path == pure-jnp reference path.
  * Chunked prefill (with padding + valid_len) == one-shot prefill.
  * decode(t, pos) == prefill logits row for the same token/position.
  * KV cache contents after prefill are independent of chunking.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model as M

CFG = M.PRESETS["tiny"]


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG)


def run_prefill(params, tokens, chunk, use_pallas=True):
    """Chunked prefill driver mirroring rust/src/engine (pad + valid_len)."""
    fn = jax.jit(M.make_prefill(CFG, chunk, use_pallas=use_pallas))
    kc, vc = M.init_kv_cache(CFG)
    pos = 0
    logits = None
    while pos < len(tokens):
        piece = tokens[pos : pos + chunk]
        valid = len(piece)
        piece = np.pad(piece, (0, chunk - valid))
        logits, kc, vc = fn(
            params, kc, vc, jnp.asarray(piece, jnp.int32),
            jnp.int32(pos), jnp.int32(valid),
        )
        last = np.asarray(logits)[valid - 1]
        pos += valid
    return last, kc, vc


def test_pallas_path_matches_ref_path(params):
    rng = np.random.default_rng(1)
    tokens = rng.integers(0, CFG.vocab, 13)
    lp, kp, vp = run_prefill(params, tokens, chunk=8, use_pallas=True)
    lr, kr, vr = run_prefill(params, tokens, chunk=8, use_pallas=False)
    np.testing.assert_allclose(lp, lr, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(kp), np.asarray(kr), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(vp), np.asarray(vr), rtol=1e-4, atol=1e-4)


def test_chunked_prefill_equals_one_shot(params):
    rng = np.random.default_rng(2)
    tokens = rng.integers(0, CFG.vocab, 16)
    l8, k8, v8 = run_prefill(params, tokens, chunk=8)
    l16, k16, v16 = run_prefill(params, tokens, chunk=16)
    np.testing.assert_allclose(l8, l16, rtol=2e-4, atol=2e-4)
    n = len(tokens)
    np.testing.assert_allclose(
        np.asarray(k8)[:, :n], np.asarray(k16)[:, :n], rtol=1e-4, atol=1e-4
    )
    np.testing.assert_allclose(
        np.asarray(v8)[:, :n], np.asarray(v16)[:, :n], rtol=1e-4, atol=1e-4
    )


def test_padding_does_not_affect_valid_logits(params):
    """Same 11 tokens through chunk=16 with different garbage padding."""
    rng = np.random.default_rng(3)
    tokens = rng.integers(0, CFG.vocab, 11)
    fn = jax.jit(M.make_prefill(CFG, 16))
    kc, vc = M.init_kv_cache(CFG)
    outs = []
    for pad_val in (0, 7, 255):
        piece = np.full(16, pad_val, np.int64)
        piece[:11] = tokens
        logits, _, _ = fn(
            params, kc, vc, jnp.asarray(piece, jnp.int32), jnp.int32(0), jnp.int32(11)
        )
        outs.append(np.asarray(logits)[:11])
    np.testing.assert_array_equal(outs[0], outs[1])
    np.testing.assert_array_equal(outs[0], outs[2])


def test_decode_consistent_with_prefill(params):
    """Greedy continuation via decode matches teacher-forced prefill logits."""
    rng = np.random.default_rng(4)
    tokens = rng.integers(0, CFG.vocab, 9)
    last, kc, vc = run_prefill(params, tokens, chunk=8)
    nxt = int(np.argmax(last))

    dec = jax.jit(M.make_decode(CFG))
    dlogits, kc, vc = dec(params, kc, vc, jnp.int32(nxt), jnp.int32(len(tokens)))

    full = np.concatenate([tokens, [nxt]])
    last2, _, _ = run_prefill(params, full, chunk=8)
    np.testing.assert_allclose(np.asarray(dlogits), last2, rtol=2e-3, atol=2e-3)


def test_decode_chain_deterministic(params):
    rng = np.random.default_rng(5)
    tokens = rng.integers(0, CFG.vocab, 6)
    outs = []
    for _ in range(2):
        last, kc, vc = run_prefill(params, tokens, chunk=8)
        dec = jax.jit(M.make_decode(CFG))
        seq = []
        t, pos = int(np.argmax(last)), len(tokens)
        for _ in range(4):
            logits, kc, vc = dec(params, kc, vc, jnp.int32(t), jnp.int32(pos))
            t = int(np.argmax(np.asarray(logits)))
            pos += 1
            seq.append(t)
        outs.append(seq)
    assert outs[0] == outs[1]


def test_kv_cache_prefix_reuse_semantics(params):
    """The paper's core trick: restoring a cached prefix + prefilling only the
    suffix must produce the same logits as prefilling the whole prompt."""
    rng = np.random.default_rng(6)
    prefix = rng.integers(0, CFG.vocab, 8)
    suffix = rng.integers(0, CFG.vocab, 5)
    full = np.concatenate([prefix, suffix])

    # one-shot over the full prompt
    want, _, _ = run_prefill(params, full, chunk=8)

    # simulate: download cached prefix state, then prefill only the suffix
    _, kc, vc = run_prefill(params, prefix, chunk=8)
    fn = jax.jit(M.make_prefill(CFG, 8))
    piece = np.pad(suffix, (0, 8 - len(suffix)))
    logits, kc, vc = fn(
        params, kc, vc, jnp.asarray(piece, jnp.int32),
        jnp.int32(len(prefix)), jnp.int32(len(suffix)),
    )
    got = np.asarray(logits)[len(suffix) - 1]
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


def test_presets_sane():
    for name, cfg in M.PRESETS.items():
        assert cfg.name == name
        assert cfg.n_heads % cfg.n_kv_heads == 0
        assert cfg.kv_bytes_per_token > 0
        assert cfg.n_params > 0
        assert all(c <= cfg.max_seq for c in cfg.prefill_chunks)
    # the "1b" preset must have a strictly larger per-token state than "270m"
    # (mirrors the paper's 9.94 MB vs 2.25 MB cache entries)
    assert (
        M.PRESETS["edge-1b"].kv_bytes_per_token
        > M.PRESETS["edge-270m"].kv_bytes_per_token
    )


def test_model_hash_distinguishes_configs():
    import dataclasses

    a = M.PRESETS["tiny"]
    b = dataclasses.replace(a, seed=a.seed + 1)
    c = dataclasses.replace(a, n_layers=a.n_layers + 1)
    assert a.model_hash() == M.PRESETS["tiny"].model_hash()
    assert a.model_hash() != b.model_hash()
    assert a.model_hash() != c.model_hash()
