"""Shared helpers for the Pallas kernels.

All kernels are authored for the TPU memory model (block-tiled VMEM residency,
MXU-shaped matmuls) but lowered with ``interpret=True`` so the resulting HLO
runs on the CPU PJRT plugin — real-TPU lowering would emit Mosaic custom-calls
the CPU client cannot execute (see DESIGN.md §Hardware-Adaptation).
"""

from __future__ import annotations

INTERPRET = True  # flipped to False only for TPU compile-target experiments

# Preferred tile edges.  On a real TPU the MXU is 128x128 and VMEM ~16 MB/core;
# we aim tiles at multiples of 8 (sublane) x 128 (lane) when shapes allow and
# degrade gracefully for the tiny shapes hypothesis throws at us.
LANE = 128
SUBLANE = 8


def pick_block(n: int, target: int) -> int:
    """Largest divisor of ``n`` that is <= ``target`` (>=1).

    Pallas grids must tile the array exactly (we do not rely on implicit
    padding semantics, which differ between interpret and compiled modes), so
    block sizes are always exact divisors.
    """
    if n <= 0:
        raise ValueError(f"block dimension must be positive, got {n}")
    b = min(n, target)
    while n % b != 0:
        b -= 1
    return b


def estimate_vmem_bytes(*block_shapes_dtypes) -> int:
    """Sum of buffer footprints for a kernel invocation, in bytes.

    Used by EXPERIMENTS.md §Perf to check each kernel's working set against
    the ~16 MB VMEM budget of a TPU core.  ``block_shapes_dtypes`` is a list
    of (shape_tuple, itemsize) pairs.
    """
    total = 0
    for shape, itemsize in block_shapes_dtypes:
        n = itemsize
        for d in shape:
            n *= d
        total += n
    return total
