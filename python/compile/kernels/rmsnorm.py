"""Pallas RMSNorm kernel (Gemma-style ``1 + w`` gain).

Grid is 1-D over row blocks; each program normalises a ``[Bn, d]`` tile held
in VMEM.  The reduction is along the lane axis, which the VPU handles without
MXU involvement — this kernel is bandwidth-bound by design and exists so the
whole transformer block lowers through Pallas (one fused region per op class).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import INTERPRET, SUBLANE, pick_block


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)  # [Bn, d]
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    normed = x * (1.0 / jnp.sqrt(var + eps))
    o_ref[...] = (normed * (1.0 + w_ref[...].astype(jnp.float32))).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows"))
def rmsnorm(
    x: jnp.ndarray,  # [n, d]
    w: jnp.ndarray,  # [d]
    eps: float = 1e-6,
    block_rows: int = 4 * SUBLANE,
) -> jnp.ndarray:
    """RMSNorm over the last axis of a rank-2 input.  Returns [n, d]."""
    n, d = x.shape
    bn = pick_block(n, block_rows)
    return pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((bn, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bn, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), x.dtype),
        interpret=INTERPRET,
    )(x, w)
