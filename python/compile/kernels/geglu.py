"""Pallas fused GeGLU feed-forward kernel.

Computes ``(gelu(x @ wg) * (x @ wu)) @ wd`` for a row block of ``x`` without
ever materialising the ``[n, ff]`` intermediate in HBM: the FFN width is
streamed through VMEM in ``block_f`` columns, and each column block's
contribution to the output is accumulated immediately (the MXU analog of
llama.cpp's fused ggml FFN op — see DESIGN.md §Hardware-Adaptation).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import INTERPRET, LANE, SUBLANE, pick_block


def _gelu_f32(x):
    c = jnp.sqrt(2.0 / jnp.pi).astype(jnp.float32)
    return 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * x**3)))


def _geglu_kernel(x_ref, wg_ref, wu_ref, wd_ref, o_ref, *, block_f: int):
    x = x_ref[...].astype(jnp.float32)  # [Bn, dm]
    bn, dm = x.shape
    ff = wg_ref.shape[1]
    nblk = ff // block_f

    if nblk == 1:
        # whole FFN width in one tile (fits VMEM for edge-sized models —
        # DESIGN.md §Perf): no loop, one fused matmul chain
        wg = wg_ref[...].astype(jnp.float32)
        wu = wu_ref[...].astype(jnp.float32)
        wd = wd_ref[...].astype(jnp.float32)
        o_ref[...] = ((_gelu_f32(x @ wg) * (x @ wu)) @ wd).astype(o_ref.dtype)
        return

    def body(j, acc):
        wg_j = wg_ref[:, pl.ds(j * block_f, block_f)].astype(jnp.float32)  # [dm, Bf]
        wu_j = wu_ref[:, pl.ds(j * block_f, block_f)].astype(jnp.float32)
        wd_j = wd_ref[pl.ds(j * block_f, block_f), :].astype(jnp.float32)  # [Bf, dm]
        g = _gelu_f32(x @ wg_j)  # [Bn, Bf]
        u = x @ wu_j
        return acc + (g * u) @ wd_j  # [Bn, dm]

    acc0 = jnp.zeros((bn, dm), jnp.float32)
    acc = jax.lax.fori_loop(0, nblk, body, acc0)
    o_ref[...] = acc.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "block_f"))
def geglu_ffn(
    x: jnp.ndarray,  # [n, dm]
    wg: jnp.ndarray,  # [dm, ff]
    wu: jnp.ndarray,  # [dm, ff]
    wd: jnp.ndarray,  # [ff, dm]
    block_rows: int = 4 * SUBLANE,
    block_f: int = 16 * LANE,
) -> jnp.ndarray:
    """Fused gated-GELU FFN.  Returns [n, dm]."""
    n, dm = x.shape
    dmg, ff = wg.shape
    assert dmg == dm and wu.shape == (dm, ff) and wd.shape == (ff, dm)
    bn = pick_block(n, block_rows)
    bf = pick_block(ff, block_f)

    return pl.pallas_call(
        functools.partial(_geglu_kernel, block_f=bf),
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((bn, dm), lambda i: (i, 0)),
            pl.BlockSpec((dm, ff), lambda i: (0, 0)),
            pl.BlockSpec((dm, ff), lambda i: (0, 0)),
            pl.BlockSpec((ff, dm), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bn, dm), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, dm), x.dtype),
        interpret=INTERPRET,
    )(x, wg, wu, wd)
