"""Pallas attention kernels: chunked prefill and single-token decode, with GQA.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's llama.cpp
baseline walks the KV cache with cache-blocked NEON loops; the TPU rethink is a
flash-attention-style schedule — the query tile stays resident in VMEM while
K/V stream through block by block, with an online-softmax accumulator so the
working set is O(Bq*D + Bk*D), never O(S).

Both kernels take *additive* masks (0 where allowed, NEG_INF where not), which
lets the model express causality, prefix length and padding in one place.

GQA is expressed in the BlockSpec index maps: query-head program ``h`` reads
KV head ``h // (H // Kh)``, so no repeated/materialised K/V ever exists.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import INTERPRET, pick_block

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Prefill: q [C, H, D] x cache [S, Kh, D] -> [C, H, D]
# ---------------------------------------------------------------------------


def _prefill_kernel(q_ref, k_ref, v_ref, mask_ref, o_ref, *, scale: float, block_k: int):
    """One (query-block, head) program: online softmax over KV blocks.

    When the whole KV cache fits one block (the common case for edge-sized
    models — see DESIGN.md §Perf), the online-softmax loop collapses to a
    single fused softmax: XLA CPU executes that ~2x faster than a 1-trip
    while loop, and on TPU it removes the loop-carried dependency.
    """
    q = q_ref[...][:, 0, :].astype(jnp.float32) * scale  # [Bq, D]
    bq, d = q.shape
    s = k_ref.shape[0]
    nblk = s // block_k

    if nblk == 1:
        k = k_ref[...][:, 0, :].astype(jnp.float32)  # [S, D]
        v = v_ref[...][:, 0, :].astype(jnp.float32)
        scores = q @ k.T + mask_ref[...].astype(jnp.float32)  # [Bq, S]
        m = jnp.max(scores, axis=-1, keepdims=True)
        p = jnp.exp(scores - m)
        out = (p @ v) / jnp.sum(p, axis=-1, keepdims=True)
        o_ref[...] = out[:, None, :].astype(o_ref.dtype)
        return

    def body(j, carry):
        m_prev, l_prev, acc_prev = carry
        kj = k_ref[pl.ds(j * block_k, block_k), 0, :].astype(jnp.float32)  # [Bk, D]
        vj = v_ref[pl.ds(j * block_k, block_k), 0, :].astype(jnp.float32)  # [Bk, D]
        mj = mask_ref[:, pl.ds(j * block_k, block_k)].astype(jnp.float32)  # [Bq, Bk]
        scores = q @ kj.T + mj  # [Bq, Bk]
        m_new = jnp.maximum(m_prev, jnp.max(scores, axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(scores - m_new[:, None])
        l_new = l_prev * alpha + jnp.sum(p, axis=-1)
        acc_new = acc_prev * alpha[:, None] + p @ vj
        return m_new, l_new, acc_new

    m0 = jnp.full((bq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    acc0 = jnp.zeros((bq, d), jnp.float32)
    _, l, acc = jax.lax.fori_loop(0, nblk, body, (m0, l0, acc0))
    out = acc / l[:, None]
    o_ref[...] = out[:, None, :].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "block_q", "block_k"))
def prefill_attention(
    q: jnp.ndarray,  # [C, H, D]
    k: jnp.ndarray,  # [S, Kh, D]
    v: jnp.ndarray,  # [S, Kh, D]
    mask: jnp.ndarray,  # [C, S] additive
    scale: float,
    block_q: int = 32,
    block_k: int = 1024,
) -> jnp.ndarray:
    """Chunked-prefill attention against the full KV cache.  Returns [C, H, D]."""
    c, h, d = q.shape
    s, kh, dk = k.shape
    assert d == dk and v.shape == k.shape and mask.shape == (c, s)
    assert h % kh == 0, f"H={h} must be a multiple of Kh={kh}"
    group = h // kh
    bq = pick_block(c, block_q)
    bk = pick_block(s, block_k)

    return pl.pallas_call(
        functools.partial(_prefill_kernel, scale=scale, block_k=bk),
        grid=(h, c // bq),
        in_specs=[
            pl.BlockSpec((bq, 1, d), lambda hh, cc: (cc, hh, 0)),  # q tile
            pl.BlockSpec((s, 1, d), lambda hh, cc: (0, hh // group, 0)),  # K (GQA map)
            pl.BlockSpec((s, 1, d), lambda hh, cc: (0, hh // group, 0)),  # V (GQA map)
            pl.BlockSpec((bq, s), lambda hh, cc: (cc, 0)),  # mask tile
        ],
        out_specs=pl.BlockSpec((bq, 1, d), lambda hh, cc: (cc, hh, 0)),
        out_shape=jax.ShapeDtypeStruct((c, h, d), q.dtype),
        interpret=INTERPRET,
    )(q, k, v, mask)


# ---------------------------------------------------------------------------
# Decode: q [H, D] x cache [S, Kh, D] -> [H, D]
# ---------------------------------------------------------------------------


def _decode_kernel(q_ref, k_ref, v_ref, mask_ref, o_ref, *, scale: float, block_k: int):
    """One head program: single query row, online softmax over KV blocks.

    Single-block fast path as in `_prefill_kernel` (see DESIGN.md §Perf).
    """
    q = q_ref[...][0, :].astype(jnp.float32) * scale  # [D]
    d = q.shape[0]
    s = k_ref.shape[0]
    nblk = s // block_k

    if nblk == 1:
        k = k_ref[...][:, 0, :].astype(jnp.float32)  # [S, D]
        v = v_ref[...][:, 0, :].astype(jnp.float32)
        scores = k @ q + mask_ref[...].astype(jnp.float32)  # [S]
        m = jnp.max(scores)
        p = jnp.exp(scores - m)
        o_ref[...] = ((p @ v) / jnp.sum(p))[None, :].astype(o_ref.dtype)
        return

    def body(j, carry):
        m_prev, l_prev, acc_prev = carry
        kj = k_ref[pl.ds(j * block_k, block_k), 0, :].astype(jnp.float32)  # [Bk, D]
        vj = v_ref[pl.ds(j * block_k, block_k), 0, :].astype(jnp.float32)
        mj = mask_ref[pl.ds(j * block_k, block_k)].astype(jnp.float32)  # [Bk]
        scores = kj @ q + mj  # [Bk]
        m_new = jnp.maximum(m_prev, jnp.max(scores))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(scores - m_new)
        l_new = l_prev * alpha + jnp.sum(p)
        acc_new = acc_prev * alpha + p @ vj
        return m_new, l_new, acc_new

    m0 = jnp.float32(NEG_INF)
    l0 = jnp.float32(0.0)
    acc0 = jnp.zeros((d,), jnp.float32)
    _, l, acc = jax.lax.fori_loop(0, nblk, body, (m0, l0, acc0))
    o_ref[...] = (acc / l)[None, :].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "block_k"))
def decode_attention(
    q: jnp.ndarray,  # [H, D]
    k: jnp.ndarray,  # [S, Kh, D]
    v: jnp.ndarray,  # [S, Kh, D]
    mask: jnp.ndarray,  # [S] additive
    scale: float,
    block_k: int = 1024,
) -> jnp.ndarray:
    """Decode-step attention (one query token).  Returns [H, D]."""
    h, d = q.shape
    s, kh, dk = k.shape
    assert d == dk and v.shape == k.shape and mask.shape == (s,)
    assert h % kh == 0
    group = h // kh
    bk = pick_block(s, block_k)

    return pl.pallas_call(
        functools.partial(_decode_kernel, scale=scale, block_k=bk),
        grid=(h,),
        in_specs=[
            pl.BlockSpec((1, d), lambda hh: (hh, 0)),
            pl.BlockSpec((s, 1, d), lambda hh: (0, hh // group, 0)),
            pl.BlockSpec((s, 1, d), lambda hh: (0, hh // group, 0)),
            pl.BlockSpec((s,), lambda hh: (0,)),
        ],
        out_specs=pl.BlockSpec((1, d), lambda hh: (hh, 0)),
        out_shape=jax.ShapeDtypeStruct((h, d), q.dtype),
        interpret=INTERPRET,
    )(q, k, v, mask)
