"""Pure-jnp reference oracles for the Pallas kernels (L1 correctness ground truth).

Every Pallas kernel in this package has an exact functional twin here, written
with plain ``jax.numpy`` ops only.  ``python/tests`` sweeps shapes/dtypes with
hypothesis and asserts ``assert_allclose(kernel(...), ref(...))``.

Conventions (shared with the kernels and with ``model.py``):
  * ``C``  — prefill chunk length (queries in this call)
  * ``S``  — max sequence length (KV-cache capacity)
  * ``H``  — number of query heads;  ``Kh`` — number of KV heads (GQA: H % Kh == 0)
  * ``D``  — head dimension;  ``dm`` — model width;  ``ff`` — FFN width
  * masks are additive: 0.0 where attention is allowed, ``NEG_INF`` elsewhere
"""

from __future__ import annotations

import jax.numpy as jnp

# Large-negative constant used for masking.  Finite (not -inf) so that fully
# masked rows produce a uniform softmax instead of NaNs; matches llama.cpp's
# behaviour of never feeding -inf into softmax.
NEG_INF = -1e30


def rmsnorm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    """Gemma-style RMSNorm: ``x * rsqrt(mean(x^2) + eps) * (1 + w)``.

    Gemma parameterizes the gain as ``1 + w`` (zero-initialised ``w``), unlike
    the Llama convention of a plain multiplicative weight.
    x: [..., dm], w: [dm].
    """
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    normed = x32 * (1.0 / jnp.sqrt(var + eps))
    return (normed * (1.0 + w.astype(jnp.float32))).astype(x.dtype)


def _gqa_expand(kv: jnp.ndarray, h: int) -> jnp.ndarray:
    """[S, Kh, D] -> [S, H, D] by repeating each KV head H/Kh times."""
    s, kh, d = kv.shape
    assert h % kh == 0, f"H={h} not a multiple of Kh={kh}"
    return jnp.repeat(kv, h // kh, axis=1)


def prefill_attention(
    q: jnp.ndarray,  # [C, H, D]
    k: jnp.ndarray,  # [S, Kh, D]
    v: jnp.ndarray,  # [S, Kh, D]
    mask: jnp.ndarray,  # [C, S] additive (0 or NEG_INF)
    scale: float,
) -> jnp.ndarray:
    """Multi-head causal attention of a prefill chunk against the KV cache.

    The cache already contains both the previously-decoded prefix *and* this
    chunk's own K/V (the model scatters them in before calling attention), so
    causality and padding are expressed entirely through ``mask``.
    Returns [C, H, D].
    """
    c, h, d = q.shape
    kx = _gqa_expand(k, h)  # [S, H, D]
    vx = _gqa_expand(v, h)
    # scores[c,h,s] = q[c,h,:] . k[s,h,:]
    scores = jnp.einsum("chd,shd->chs", q.astype(jnp.float32), kx.astype(jnp.float32))
    scores = scores * scale + mask[:, None, :].astype(jnp.float32)
    p = jnp.exp(scores - jnp.max(scores, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("chs,shd->chd", p, vx.astype(jnp.float32))
    return out.astype(q.dtype)


def decode_attention(
    q: jnp.ndarray,  # [H, D]
    k: jnp.ndarray,  # [S, Kh, D]
    v: jnp.ndarray,  # [S, Kh, D]
    mask: jnp.ndarray,  # [S] additive
    scale: float,
) -> jnp.ndarray:
    """Single-token (decode-step) attention.  Returns [H, D]."""
    out = prefill_attention(q[None, :, :], k, v, mask[None, :], scale)
    return out[0]


def gelu(x: jnp.ndarray) -> jnp.ndarray:
    """tanh-approximated GELU (the variant Gemma uses)."""
    x32 = x.astype(jnp.float32)
    c = jnp.sqrt(2.0 / jnp.pi).astype(jnp.float32)
    return (0.5 * x32 * (1.0 + jnp.tanh(c * (x32 + 0.044715 * x32**3)))).astype(x.dtype)


def geglu_ffn(
    x: jnp.ndarray,  # [n, dm]
    wg: jnp.ndarray,  # [dm, ff]
    wu: jnp.ndarray,  # [dm, ff]
    wd: jnp.ndarray,  # [ff, dm]
) -> jnp.ndarray:
    """Gated-GELU feed-forward: ``(gelu(x@wg) * (x@wu)) @ wd``.  Returns [n, dm]."""
    g = gelu(jnp.dot(x, wg))
    u = jnp.dot(x, wu)
    return jnp.dot(g * u, wd)
