"""L2: Gemma-3-style transformer in JAX, calling the Pallas kernels.

This is the *compute graph* half of the reproduction.  The paper runs Gemma-3
270M (low-end) / 1B (high-end) via llama.cpp; weights are gated downloads, so
we instantiate the same architecture family — RMSNorm sandwich, RoPE, GQA
attention, GeGLU FFN, tied embeddings — with random weights.  Every metric the
paper reports is latency or state size, both functions of architecture shape
only (DESIGN.md §Substitutions).

Two entry points are AOT-lowered per model preset by ``aot.py``:

  ``prefill(params, kcache, vcache, tokens[C], pos, valid_len)``
      -> (logits[C, V], kcache', vcache')
  ``decode(params, kcache, vcache, token, pos)``
      -> (logits[V], kcache', vcache')

The KV caches are dense ``[L, S, Kh, D]`` tensors threaded through every call;
the rust engine owns them between calls, serialises them as the paper's
``llama_state_get_data()`` blob, and ships them to the cache box.

Parameters are *inputs* (not baked constants) so the HLO stays small and one
loader serves all presets.  Layers are stacked on a leading ``L`` axis and the
block is applied with ``lax.scan``, which keeps the lowered module compact
(one fused layer body) and compile time flat in depth.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import attention as attn_k
from .kernels import geglu as geglu_k
from .kernels import ref
from .kernels import rmsnorm as rms_k

NEG_INF = ref.NEG_INF


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture hyper-parameters (the 'model card' the catalog hashes)."""

    name: str
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    max_seq: int
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    seed: int = 20260711
    prefill_chunks: Tuple[int, ...] = (16, 64, 128)

    def __post_init__(self):
        assert self.n_heads % self.n_kv_heads == 0

    @property
    def kv_bytes_per_token(self) -> int:
        """f32 K+V bytes contributed by one token across all layers."""
        return 2 * self.n_layers * self.n_kv_heads * self.head_dim * 4

    @property
    def n_params(self) -> int:
        c = self
        per_layer = (
            4 * c.d_model  # four norms
            + c.d_model * c.n_heads * c.head_dim * 2  # wq, wo
            + c.d_model * c.n_kv_heads * c.head_dim * 2  # wk, wv
            + 3 * c.d_model * c.d_ff  # wg, wu, wd
        )
        return c.vocab * c.d_model + c.d_model + c.n_layers * per_layer

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self), sort_keys=True)

    def model_hash(self) -> str:
        """Hex digest binding cached states to (architecture, weights-seed).

        This is the metadata the paper folds into the catalog hash so states
        from different model configurations or quantization settings never
        collide (paper §3.1, Figure 3 top).
        """
        return hashlib.sha256(self.to_json().encode()).hexdigest()[:16]


# Presets.  Sizes are scaled so CPU-PJRT inference stays interactive while the
# KV-state-per-token and parameter ratios between "270m" and "1b" mirror the
# paper's 2.25 MB vs 9.94 MB cache entries (see DESIGN.md §Substitutions).
PRESETS: Dict[str, ModelConfig] = {
    "tiny": ModelConfig(
        name="tiny", vocab=512, d_model=64, n_layers=2, n_heads=4, n_kv_heads=2,
        head_dim=16, d_ff=128, max_seq=768, prefill_chunks=(8, 16, 64),
    ),
    "edge-270m": ModelConfig(
        name="edge-270m", vocab=4096, d_model=320, n_layers=6, n_heads=4,
        n_kv_heads=1, head_dim=80, d_ff=1280, max_seq=768,
        prefill_chunks=(16, 64, 128),
    ),
    "edge-1b": ModelConfig(
        name="edge-1b", vocab=4096, d_model=512, n_layers=10, n_heads=8,
        n_kv_heads=4, head_dim=64, d_ff=2048, max_seq=768,
        prefill_chunks=(16, 64, 128),
    ),
}

# Deterministic parameter order — the single source of truth shared with
# aot.py's params.bin manifest and the rust loader.
PARAM_ORDER = (
    "embed",          # [V, dm]
    "final_norm",     # [dm]
    "ln_attn_pre",    # [L, dm]
    "wq",             # [L, dm, H*D]
    "wk",             # [L, dm, Kh*D]
    "wv",             # [L, dm, Kh*D]
    "wo",             # [L, H*D, dm]
    "ln_attn_post",   # [L, dm]
    "ln_ffn_pre",     # [L, dm]
    "wg",             # [L, dm, ff]
    "wu",             # [L, dm, ff]
    "wd",             # [L, ff, dm]
    "ln_ffn_post",    # [L, dm]
)


def param_shapes(cfg: ModelConfig) -> Dict[str, Tuple[int, ...]]:
    L, dm, H, Kh, D, ff, V = (
        cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
        cfg.head_dim, cfg.d_ff, cfg.vocab,
    )
    return {
        "embed": (V, dm),
        "final_norm": (dm,),
        "ln_attn_pre": (L, dm),
        "wq": (L, dm, H * D),
        "wk": (L, dm, Kh * D),
        "wv": (L, dm, Kh * D),
        "wo": (L, H * D, dm),
        "ln_attn_post": (L, dm),
        "ln_ffn_pre": (L, dm),
        "wg": (L, dm, ff),
        "wu": (L, dm, ff),
        "wd": (L, ff, dm),
        "ln_ffn_post": (L, dm),
    }


def init_params(cfg: ModelConfig) -> Dict[str, jnp.ndarray]:
    """Seeded random init (truncated-normal-ish scaled by fan-in)."""
    shapes = param_shapes(cfg)
    rng = np.random.default_rng(cfg.seed)
    params = {}
    for name in PARAM_ORDER:
        shape = shapes[name]
        if name.startswith(("ln_", "final_norm")):
            arr = np.zeros(shape, np.float32)  # Gemma norms: gain = 1 + w, w=0
        elif name == "embed":
            arr = rng.standard_normal(shape).astype(np.float32) * 0.02
        else:
            fan_in = shape[-2]
            arr = rng.standard_normal(shape).astype(np.float32) / math.sqrt(fan_in)
        params[name] = jnp.asarray(arr)
    return params


def kv_cache_shape(cfg: ModelConfig) -> Tuple[int, int, int, int]:
    return (cfg.n_layers, cfg.max_seq, cfg.n_kv_heads, cfg.head_dim)


def init_kv_cache(cfg: ModelConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    shape = kv_cache_shape(cfg)
    return jnp.zeros(shape, jnp.float32), jnp.zeros(shape, jnp.float32)


# ---------------------------------------------------------------------------
# RoPE (GPT-NeoX pairing: first half / second half of the head dim)
# ---------------------------------------------------------------------------


def _rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: [C, Hx, D], positions: [C] int32.  Rotates each head vector."""
    c, hx, d = x.shape
    half = d // 2
    freqs = jnp.exp(
        -math.log(theta) * (2.0 * jnp.arange(half, dtype=jnp.float32) / d)
    )  # [half]
    ang = positions.astype(jnp.float32)[:, None] * freqs[None, :]  # [C, half]
    cos = jnp.cos(ang)[:, None, :]  # [C, 1, half]
    sin = jnp.sin(ang)[:, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


# ---------------------------------------------------------------------------
# Transformer block (scanned over layers)
# ---------------------------------------------------------------------------


def _layer(cfg: ModelConfig, x, kc_l, vc_l, lp, positions, mask, use_pallas: bool):
    """One transformer layer.

    x: [C, dm]; kc_l/vc_l: [S, Kh, D]; lp: dict of this layer's params;
    positions: [C] absolute token positions; mask: [C, S] additive.
    Returns (x', kc_l', vc_l').
    """
    C = x.shape[0]
    H, Kh, D = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    scale = 1.0 / math.sqrt(D)

    rms = (lambda t, w: rms_k.rmsnorm(t, w, cfg.norm_eps)) if use_pallas else (
        lambda t, w: ref.rmsnorm(t, w, cfg.norm_eps)
    )

    # --- attention sub-block (pre/post sandwich norms, Gemma-2/3 style) ---
    h = rms(x, lp["ln_attn_pre"])
    q = (h @ lp["wq"]).reshape(C, H, D)
    k = (h @ lp["wk"]).reshape(C, Kh, D)
    v = (h @ lp["wv"]).reshape(C, Kh, D)
    q = _rope(q, positions, cfg.rope_theta)
    k = _rope(k, positions, cfg.rope_theta)
    # Scatter this chunk's K/V into the cache at the chunk origin.  positions
    # is contiguous (pos .. pos+C), so one dynamic_update_slice suffices.
    kc_l = jax.lax.dynamic_update_slice(kc_l, k, (positions[0], 0, 0))
    vc_l = jax.lax.dynamic_update_slice(vc_l, v, (positions[0], 0, 0))
    if use_pallas:
        o = attn_k.prefill_attention(q, kc_l, vc_l, mask, scale)
    else:
        o = ref.prefill_attention(q, kc_l, vc_l, mask, scale)
    o = o.reshape(C, H * D) @ lp["wo"]
    x = x + rms(o, lp["ln_attn_post"])

    # --- FFN sub-block ---
    h = rms(x, lp["ln_ffn_pre"])
    f = geglu_k.geglu_ffn(h, lp["wg"], lp["wu"], lp["wd"]) if use_pallas else (
        ref.geglu_ffn(h, lp["wg"], lp["wu"], lp["wd"])
    )
    x = x + rms(f, lp["ln_ffn_post"])
    return x, kc_l, vc_l


_LAYER_KEYS = (
    "ln_attn_pre", "wq", "wk", "wv", "wo", "ln_attn_post",
    "ln_ffn_pre", "wg", "wu", "wd", "ln_ffn_post",
)


def _forward(cfg: ModelConfig, params, kcache, vcache, tokens, pos, valid_len,
              use_pallas: bool, unroll_layers: bool = False):
    """Shared prefill/decode body.

    tokens: [C] int32 (C static); pos: scalar int32 (chunk origin in the
    sequence); valid_len: scalar int32 (tokens[valid_len:] are padding).
    Returns (logits [C, V], kcache', vcache').
    """
    C = tokens.shape[0]
    S = cfg.max_seq

    x = params["embed"][tokens] * math.sqrt(cfg.d_model)  # [C, dm]
    positions = pos + jnp.arange(C, dtype=jnp.int32)

    # Additive mask: query row r (absolute position pos+r) may attend to
    # absolute cache positions s <= pos+r.  Padding rows (r >= valid_len)
    # compute garbage that is (a) never read as logits and (b) overwritten in
    # the cache by the next chunk, which starts at pos+valid_len.
    cols = jnp.arange(S, dtype=jnp.int32)[None, :]
    allowed = cols <= positions[:, None]
    mask = jnp.where(allowed, 0.0, NEG_INF).astype(jnp.float32)

    layer_params = {k: params[k] for k in _LAYER_KEYS}

    def scan_body(x, xs):
        lp, kc_l, vc_l = xs
        x, kc_l, vc_l = _layer(cfg, x, kc_l, vc_l, lp, positions, mask, use_pallas)
        return x, (kc_l, vc_l)

    # Unrolling the layer loop lets XLA fuse across layer boundaries, which
    # is a measured 1.8x win for the latency-critical decode step on CPU-PJRT
    # (27.5 -> 15.3 ms on edge-270m).  Prefill is throughput-bound over big
    # matmuls where the rolled loop's smaller code wins instead (47 -> 55 ms
    # unrolled), so each entry point chooses (EXPERIMENTS.md §Perf).
    x, (kcache, vcache) = jax.lax.scan(
        scan_body, x, (layer_params, kcache, vcache), unroll=unroll_layers
    )

    x = (rms_k.rmsnorm if use_pallas else ref.rmsnorm)(
        x, params["final_norm"], cfg.norm_eps
    )
    logits = x @ params["embed"].T  # tied embeddings
    return logits, kcache, vcache


def make_prefill(cfg: ModelConfig, chunk: int, use_pallas: bool = True):
    """Build the prefill entry point for a fixed chunk size."""

    def prefill(params, kcache, vcache, tokens, pos, valid_len):
        assert tokens.shape == (chunk,)
        return _forward(cfg, params, kcache, vcache, tokens, pos, valid_len,
                        use_pallas, unroll_layers=False)

    return prefill


def make_decode(cfg: ModelConfig, use_pallas: bool = True):
    """Build the single-token decode entry point."""

    def decode(params, kcache, vcache, token, pos):
        tokens = jnp.reshape(token, (1,)).astype(jnp.int32)
        logits, kcache, vcache = _forward(
            cfg, params, kcache, vcache, tokens, pos,
            jnp.int32(1), use_pallas, unroll_layers=True,
        )
        return logits[0], kcache, vcache

    return decode


def example_args(cfg: ModelConfig, chunk: int):
    """ShapeDtypeStructs for lowering the prefill entry point."""
    f32 = jnp.float32
    params = {k: jax.ShapeDtypeStruct(v, f32) for k, v in param_shapes(cfg).items()}
    kv = jax.ShapeDtypeStruct(kv_cache_shape(cfg), f32)
    tokens = jax.ShapeDtypeStruct((chunk,), jnp.int32)
    scalar = jax.ShapeDtypeStruct((), jnp.int32)
    return params, kv, kv, tokens, scalar, scalar


def example_args_decode(cfg: ModelConfig):
    f32 = jnp.float32
    params = {k: jax.ShapeDtypeStruct(v, f32) for k, v in param_shapes(cfg).items()}
    kv = jax.ShapeDtypeStruct(kv_cache_shape(cfg), f32)
    scalar = jax.ShapeDtypeStruct((), jnp.int32)
    return params, kv, kv, scalar, scalar
