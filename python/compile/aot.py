"""AOT export: lower the L2 model to HLO *text* + params.bin per preset.

This is the only place Python touches the pipeline; ``make artifacts`` runs it
once and the rust binary is self-contained afterwards.

Interchange format is HLO **text**, not a serialized HloModuleProto: jax>=0.5
emits protos with 64-bit instruction ids which the xla crate's bundled
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Layout written under ``--out`` (default ../artifacts):

    <out>/<preset>/meta.json          config + model_hash + manifests
    <out>/<preset>/params.bin         all parameters, f32 LE, PARAM_ORDER
    <out>/<preset>/decode.hlo.txt
    <out>/<preset>/prefill_<C>.hlo.txt   (one per configured chunk size)

meta.json's ``input_order`` / per-entry ``inputs`` record the exact positional
parameter order of each HLO entry computation (jax flattens the params dict in
sorted-key order); the rust runtime feeds literals in that order.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-compatible path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def sorted_param_names() -> list:
    """jax flattens dicts in sorted-key order; this is the runtime contract."""
    return sorted(M.PARAM_ORDER)


def export_params(cfg: M.ModelConfig, out_dir: str) -> list:
    """Write params.bin (f32 LE, sorted-name order) and return the manifest."""
    params = M.init_params(cfg)
    manifest = []
    offset = 0
    path = os.path.join(out_dir, "params.bin")
    with open(path, "wb") as f:
        for name in sorted_param_names():
            arr = np.asarray(params[name], dtype="<f4")
            data = arr.tobytes()
            manifest.append(
                {
                    "name": name,
                    "shape": list(arr.shape),
                    "dtype": "f32",
                    "offset_bytes": offset,
                    "size_bytes": len(data),
                }
            )
            f.write(data)
            offset += len(data)
    return manifest


def scalar_spec() -> dict:
    return {"shape": [], "dtype": "i32"}


def entry_io(cfg: M.ModelConfig, chunk: int | None) -> tuple:
    """(inputs, outputs) descriptors for one HLO entry computation."""
    kv = {"shape": list(M.kv_cache_shape(cfg)), "dtype": "f32"}
    shapes = M.param_shapes(cfg)
    inputs = [
        {"name": n, "shape": list(shapes[n]), "dtype": "f32", "role": "param"}
        for n in sorted_param_names()
    ]
    inputs.append({"name": "kcache", "role": "kv", **kv})
    inputs.append({"name": "vcache", "role": "kv", **kv})
    if chunk is None:
        inputs.append({"name": "token", "role": "token", **scalar_spec()})
        inputs.append({"name": "pos", "role": "pos", **scalar_spec()})
        outputs = [
            {"name": "logits", "shape": [cfg.vocab], "dtype": "f32"},
            {"name": "kcache", **kv},
            {"name": "vcache", **kv},
        ]
    else:
        inputs.append(
            {"name": "tokens", "role": "tokens", "shape": [chunk], "dtype": "i32"}
        )
        inputs.append({"name": "pos", "role": "pos", **scalar_spec()})
        inputs.append({"name": "valid_len", "role": "valid_len", **scalar_spec()})
        outputs = [
            {"name": "logits", "shape": [chunk, cfg.vocab], "dtype": "f32"},
            {"name": "kcache", **kv},
            {"name": "vcache", **kv},
        ]
    return inputs, outputs


def export_preset(cfg: M.ModelConfig, out_root: str, use_pallas: bool = True) -> dict:
    out_dir = os.path.join(out_root, cfg.name)
    os.makedirs(out_dir, exist_ok=True)
    t0 = time.time()

    entries = []

    # --- decode ---
    decode = M.make_decode(cfg, use_pallas=use_pallas)
    args = M.example_args_decode(cfg)
    hlo = to_hlo_text(jax.jit(decode, keep_unused=True).lower(*args))
    with open(os.path.join(out_dir, "decode.hlo.txt"), "w") as f:
        f.write(hlo)
    ins, outs = entry_io(cfg, None)
    entries.append(
        {"name": "decode", "hlo": "decode.hlo.txt", "chunk": 0,
         "inputs": ins, "outputs": outs}
    )
    print(f"  [{cfg.name}] decode lowered ({len(hlo)} chars)")

    # --- prefill variants ---
    for chunk in cfg.prefill_chunks:
        prefill = M.make_prefill(cfg, chunk, use_pallas=use_pallas)
        args = M.example_args(cfg, chunk)
        hlo = to_hlo_text(jax.jit(prefill, keep_unused=True).lower(*args))
        fname = f"prefill_{chunk}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(hlo)
        ins, outs = entry_io(cfg, chunk)
        entries.append(
            {"name": f"prefill_{chunk}", "hlo": fname, "chunk": chunk,
             "inputs": ins, "outputs": outs}
        )
        print(f"  [{cfg.name}] prefill_{chunk} lowered ({len(hlo)} chars)")

    params_manifest = export_params(cfg, out_dir)

    meta = {
        "format_version": 1,
        "config": json.loads(cfg.to_json()),
        "model_hash": cfg.model_hash(),
        "kv_cache_shape": list(M.kv_cache_shape(cfg)),
        "kv_bytes_per_token": cfg.kv_bytes_per_token,
        "n_params": cfg.n_params,
        "input_order": sorted_param_names()
        + ["kcache", "vcache", "<tokens-or-token>", "pos", "<valid_len:prefill-only>"],
        "params_file": "params.bin",
        "params": params_manifest,
        "entries": entries,
        "use_pallas": use_pallas,
        "lowered_with": {"jax": jax.__version__},
    }
    with open(os.path.join(out_dir, "meta.json"), "w") as f:
        json.dump(meta, f, indent=2)
    print(f"  [{cfg.name}] exported in {time.time() - t0:.1f}s -> {out_dir}")
    return meta


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifacts root dir")
    ap.add_argument(
        "--presets", default="tiny,edge-270m,edge-1b",
        help="comma-separated preset names (see model.PRESETS)",
    )
    ap.add_argument(
        "--no-pallas", action="store_true",
        help="lower the pure-jnp reference path instead of the Pallas kernels",
    )
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    for name in args.presets.split(","):
        name = name.strip()
        if name not in M.PRESETS:
            print(f"unknown preset {name!r}; have {list(M.PRESETS)}", file=sys.stderr)
            sys.exit(2)
        print(f"exporting {name} ...")
        export_preset(M.PRESETS[name], args.out, use_pallas=not args.no_pallas)
    print("AOT export complete.")


if __name__ == "__main__":
    main()
