# Repo task runner (https://just.systems); plain `sh scripts/check.sh` works
# too when just is unavailable.

# build + test + clippy on the rust crate (tier-1 gate)
check:
    sh scripts/check.sh

# tier-1 only (no clippy)
test:
    sh scripts/check.sh --no-clippy

# unit + property tests only — the fast inner loop (no engine-backed
# integration suites, no clippy)
test-fast:
    cd rust && cargo test -q --lib && cargo test -q --test prop_invariants

# the failure-injection suite on its own (corrupt/truncated chunks, stale
# alias geometry, dead-server degradation)
test-failures:
    cd rust && cargo test -q --test integration_failures

# regenerate the paper-table benches (release mode)
bench:
    cd rust && cargo bench --bench substrate_micro && cargo bench --bench table3_breakdown
