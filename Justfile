# Repo task runner (https://just.systems); plain `sh scripts/check.sh` works
# too when just is unavailable.

# build + test + clippy on the rust crate (tier-1 gate)
check:
    sh scripts/check.sh

# tier-1 only (no clippy)
test:
    sh scripts/check.sh --no-clippy

# regenerate the paper-table benches (release mode)
bench:
    cd rust && cargo bench --bench substrate_micro && cargo bench --bench table3_breakdown
