# Repo task runner (https://just.systems); plain `sh scripts/check.sh` works
# too when just is unavailable.

# build + test + clippy on the rust crate (tier-1 gate)
check:
    sh scripts/check.sh

# tier-1 only (no clippy)
test:
    sh scripts/check.sh --no-clippy

# unit + property tests only — the fast inner loop (no engine-backed
# integration suites, no clippy)
test-fast:
    cd rust && cargo test -q --lib && cargo test -q --test prop_invariants

# the failure-injection suite on its own (corrupt/truncated chunks, stale
# alias geometry, dead-server degradation)
test-failures:
    cd rust && cargo test -q --test integration_failures

# regenerate the paper-table benches (release mode)
bench:
    cd rust && cargo bench --bench substrate_micro && cargo bench --bench table3_breakdown

# streaming-assembly bench, full sweep (emits BENCH_streaming.json)
bench-streaming:
    cd rust && cargo bench --bench streaming_assembly

# peer-fabric bench, full sweep (emits BENCH_peer_fabric.json): 2-peer
# multi-source fetch vs 1-peer, and hit-rate retention through a mid-trace
# peer death
bench-peers-full:
    cd rust && cargo bench --bench peer_fabric

# the same bench with tiny parameters — the check.sh smoke gate: asserts
# 2-peer striping strictly beats 1-peer and that a trace survives a peer
# death via survivor re-planning
bench-peers:
    cd rust && EDGECACHE_SMOKE=1 cargo bench --bench peer_fabric

# the same bench with tiny parameters — the check.sh smoke gate: it asserts
# streaming strictly beats store-and-forward and that restore completes
# within ~1 chunk-decode of last-byte arrival
bench-smoke:
    cd rust && EDGECACHE_SMOKE=1 cargo bench --bench streaming_assembly

# placement bench, full sweep (emits BENCH_placement.json): ring vs p2c on
# byte balance, post-reboot (catalog-less) hit rate, and post-death
# re-replication via fabric::repair_entry
bench-placement-full:
    cd rust && cargo bench --bench placement

# the same bench with tiny parameters — the check.sh smoke gate: asserts
# the ring's post-reboot hit rate strictly beats p2c's, ring byte imbalance
# stays under the documented bound, and repair restores the replication
# factor after a peer death
bench-placement:
    cd rust && EDGECACHE_SMOKE=1 cargo bench --bench placement

# churn bench, full sweep (emits BENCH_churn.json): rolling reboots + a
# permanent peer death with heartbeat membership vs a no-heartbeat
# ablation, a stalled (accepted-but-silent) head claimer, and seeded
# mid-run link-degradation flaps
bench-churn-full:
    cd rust && cargo bench --bench churn

# the same bench with tiny parameters — the check.sh smoke gate: asserts
# heal+repair restores the replication factor and strictly beats the
# ablation's post-death hit rate, stalled restores stay within one
# deadline budget, and zero operations wedge
bench-churn:
    cd rust && EDGECACHE_SMOKE=1 cargo bench --bench churn

# the liveness suite on its own (stalled-peer budget bound, membership
# heal loop over a real reboot)
test-liveness:
    cd rust && cargo test -q --test integration_liveness

# fetch-plan bench, full sweep (emits BENCH_plan.json): per-chunk mixed
# plans vs all-fetch / all-recompute / whole-range break-even across the
# device x link x state-scale x prefix grid
bench-plan-full:
    cd rust && cargo bench --bench fetch_plan

# the same bench with a reduced grid — the check.sh smoke gate: asserts
# mixed plans dominate both extremes, strictly win on slow-link/fast-device
# cells, and match the exhaustive 2^k oracle
bench-plan:
    cd rust && EDGECACHE_SMOKE=1 cargo bench --bench fetch_plan

# the plan-oracle suite on its own (brute-force optimality, monotonicity
# laws, prefix-shape invariant)
test-plan:
    cd rust && cargo test -q --test plan_oracle

# gossip bench, full sweep (emits BENCH_gossip.json): fleet-wide death
# detection with SWIM digests vs the per-client-heartbeat ablation, an
# asymmetric partition survived with zero false deaths (indirect probes +
# incarnation refutation), and byte-fault schedules restored bit-exact via
# the rescue ladder
bench-gossip-full:
    cd rust && cargo bench --bench gossip

# the same bench with tiny parameters — the check.sh smoke gate: asserts
# gossiped detection strictly beats per-client detection for >= 2 of 3
# clients, zero false-positive deaths under the partition schedule, and
# every byte fault ends in a bit-exact restored prefix
bench-gossip:
    cd rust && EDGECACHE_SMOKE=1 cargo bench --bench gossip

# the SWIM law suite on its own (merge commutativity/idempotence/order
# convergence, incarnation refutation, byte-fault rejection granularity)
test-gossip:
    cd rust && cargo test -q --test gossip_laws

# fleet serving bench, full ramp (emits BENCH_fleet.json): thousands of
# Zipf-driven simulated clients against the poll+sharded serving core vs
# the thread-per-connection ablation — p50/p99/p999 TTFT, hit/shed rates,
# per-box saturation, max sustained clients
bench-fleet-full:
    cd rust && cargo bench --bench fleet

# the same bench with tiny parameters — the check.sh smoke gate: exercises
# both serving cores end-to-end and asserts the harness mechanics (no op
# lost without a verdict, zero wedged poll clients); the strict p99 /
# sustained-clients comparisons only gate the full run
bench-fleet:
    cd rust && EDGECACHE_SMOKE=1 cargo bench --bench fleet

# the serving-core suite on its own (sharded-store stress with torn-read
# detection, poll vs threads reply identity, deterministic admission
# shedding + recovery, many-connection readiness multiplexing)
test-serve:
    cd rust && cargo test -q --test serve_core

# semantic-tier bench, full run (emits BENCH_semantic.json): a paraphrased
# workload through semantic matching vs the --no-semantic ablation under
# paced prefill — hit rate, mean TTFT, false-probe accounting, and
# byte-identical responses across arms
bench-semantic-full:
    cd rust && cargo bench --bench semantic

# the same bench with tiny parameters — the check.sh smoke gate: asserts
# the ablation/exact arms send zero semantic probes, the semantic arm
# strictly improves reuse, accounting closes (matched_on == matched_off +
# tokens_recovered), and every response is bit-identical across arms
bench-semantic:
    cd rust && EDGECACHE_SMOKE=1 cargo bench --bench semantic

# the semantic-tier suite on its own (sketch wire roundtrip, legacy-peer
# degradation, verification gate vs a maliciously-close sketch, paraphrase
# prefix recovery, the --no-semantic ablation, repair sweep healing)
test-semantic:
    cd rust && cargo test -q --test semantic_tier
