#!/usr/bin/env sh
# Mechanical gate for the rust/ crate: build, test, lint.  Run before every
# PR — the hot-path refactors (zero-copy blob pipeline, chunk-compressed
# range transfers) regress silently without it.
#
# Usage: scripts/check.sh [--no-clippy]
set -eu

cd "$(dirname "$0")/../rust"

if ! command -v cargo >/dev/null 2>&1; then
    echo "!!==================================================================!!" >&2
    echo "!! WARNING: no cargo toolchain on PATH — the entire gate is skipped !!" >&2
    echo "!! Nothing was built, tested, formatted or linted.                  !!" >&2
    echo "!!==================================================================!!" >&2
    exit 0
fi

# Watchdog: the liveness/churn suites intentionally park sockets and kill
# servers mid-operation; a regression there wedges instead of failing.
# Cap every test/bench invocation so the gate itself can never hang.
if command -v timeout >/dev/null 2>&1; then
    WATCHDOG="timeout 900"
else
    WATCHDOG=""
fi

# Disabled tests must point at a ROADMAP item, or they rot: any #[ignore]
# whose attribute line lacks a "ROADMAP" marker fails the gate.
echo "== #[ignore] audit =="
ignored=$(grep -rn '#\[ignore' src tests benches 2>/dev/null | grep -v 'ROADMAP' || true)
if [ -n "$ignored" ]; then
    echo "ignored tests without a linked ROADMAP item:" >&2
    echo "$ignored" >&2
    exit 1
fi

# Formatting gate: rustfmt ships as a rustup component and may be absent
# from minimal toolchains — skip loudly rather than fail the whole gate.
echo "== cargo fmt --check =="
if cargo fmt --version >/dev/null 2>&1; then
    cargo fmt --check
else
    echo "!! WARNING: rustfmt unavailable — formatting NOT checked !!" >&2
fi

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
$WATCHDOG cargo test -q

# The failure-injection suite is the safety net for the chunk-compressed
# state path (corrupt chunks, truncation, stale aliases, dead servers);
# run it explicitly so a filtered `cargo test` can never skip it silently.
echo "== cargo test -q --test integration_failures =="
$WATCHDOG cargo test -q --test integration_failures

# The peer-fabric suite covers the multi-box failure ladder (dead shares,
# dead head peers, survivor re-planning) with engine-free tests that always
# run; keep it un-skippable the same way.
echo "== cargo test -q --test integration_fabric =="
$WATCHDOG cargo test -q --test integration_fabric

# The liveness suite pins the deadline-budget guarantee: a stalled
# (accepted-but-silent) peer delays a restore by at most one op budget,
# and the heartbeat loop detects death + recovery on a rebooted address.
echo "== cargo test -q --test integration_liveness =="
$WATCHDOG cargo test -q --test integration_liveness

# The plan-oracle suite proves the per-chunk fetch planner cost-minimal
# against brute-force 2^k enumeration plus monotonicity laws; it is pure
# model code (no sockets, no engine) and must always run.
echo "== cargo test -q --test plan_oracle =="
$WATCHDOG cargo test -q --test plan_oracle

# The SWIM law suite pins the gossip layer's algebra (digest merge is
# commutative/idempotent/order-convergent, higher incarnations refute
# stale suspicion) and the byte-fault model (damaged chunks are rejected
# chunk-granularly, never committing a bad row).
echo "== cargo test -q --test gossip_laws =="
$WATCHDOG cargo test -q --test gossip_laws

# The semantic-tier suite pins the sketch layer's contract: wire-roundtrip
# of sketch sections, legacy boxes degrading to exact-only without losing
# state sync, the verification gate refusing a maliciously-close sketch
# with zero real overlap, paraphrase prefix recovery across clients, and
# the proactive repair sweep re-publishing deleted replicas.
echo "== cargo test -q --test semantic_tier =="
$WATCHDOG cargo test -q --test semantic_tier

# The serving-core suite pins the fleet-scale substrate: sharded-store
# stress with uniform-fill torn-read detection and honest byte accounting,
# poll vs thread reply identity, deterministic admission shedding with
# per-op (not per-connection) recovery, and readiness multiplexing across
# more connections than workers with zero wedged clients.
echo "== cargo test -q --test serve_core =="
$WATCHDOG cargo test -q --test serve_core

# Fleet serving smoke (`just bench-fleet`): tiny Zipf ramp through both
# serving cores — asserts every op ends in a hit/miss/shed verdict and the
# poll core wedges zero clients; the strict tail-latency and
# max-sustained-clients comparisons gate the full run only.
echo "== fleet serving smoke (EDGECACHE_SMOKE=1) =="
$WATCHDOG env EDGECACHE_SMOKE=1 cargo bench --bench fleet

# Streaming-assembly smoke (`just bench-smoke`): a tiny-parameter run of the
# overlap bench whose built-in assertions pin the hot-path claim — streaming
# beats store-and-forward and restore completes ~1 chunk-decode after the
# last byte.
echo "== streaming assembly smoke (EDGECACHE_SMOKE=1) =="
$WATCHDOG env EDGECACHE_SMOKE=1 cargo bench --bench streaming_assembly

# Peer-fabric smoke (`just bench-peers`): asserts 2-peer multi-source
# fetch strictly beats 1-peer on the shaped link, and that a mid-trace
# peer death completes the trace via survivor re-planning (hit rate 1.0).
echo "== peer fabric smoke (EDGECACHE_SMOKE=1) =="
$WATCHDOG env EDGECACHE_SMOKE=1 cargo bench --bench peer_fabric

# Placement smoke (`just bench-placement`): ring vs p2c — asserts the
# ring's post-reboot (catalog-less) hit rate strictly beats p2c's, ring
# byte imbalance stays under the documented bound, and ring-driven repair
# restores the replication factor after a peer death.
echo "== placement smoke (EDGECACHE_SMOKE=1) =="
$WATCHDOG env EDGECACHE_SMOKE=1 cargo bench --bench placement

# Churn smoke (`just bench-churn`): rolling reboots + a permanent peer
# death — asserts the heartbeat+deadline run restores the replication
# factor and strictly beats the no-heartbeat ablation on post-death hit
# rate, every stalled restore stays within one deadline budget, and zero
# operations wedge.
echo "== churn smoke (EDGECACHE_SMOKE=1) =="
$WATCHDOG env EDGECACHE_SMOKE=1 cargo bench --bench churn

# Fetch-plan smoke (`just bench-plan`): the analytic device x link sweep —
# asserts mixed plans dominate both extremes everywhere, strictly win on
# the slow-link/fast-device cells, never lose >5% to the binary policy,
# and match the exhaustive oracle on every enumerable cell.
echo "== fetch plan smoke (EDGECACHE_SMOKE=1) =="
$WATCHDOG env EDGECACHE_SMOKE=1 cargo bench --bench fetch_plan

# Gossip smoke (`just bench-gossip`): the SWIM fleet harness — asserts
# gossiped death detection strictly beats per-client detection for >= 2 of
# 3 staggered clients, an asymmetric partition produces zero false-positive
# deaths (indirect probes + incarnation refutation, hit rate 1.0 through
# head rotation), and every scripted byte fault ends in a bit-exact
# restored prefix via the rescue ladder.
echo "== gossip smoke (EDGECACHE_SMOKE=1) =="
$WATCHDOG env EDGECACHE_SMOKE=1 cargo bench --bench gossip

# Semantic smoke (`just bench-semantic`): the paraphrased-workload bench —
# asserts the --no-semantic and exact-repeat arms send zero semantic
# probes, the semantic arm strictly improves reuse and matched tokens,
# accounting closes (matched_on == matched_off + tokens_recovered), and
# every paraphrase response is byte-identical across arms (reused state
# never changes output); the strict mean-TTFT comparison gates the paced
# full run only.
echo "== semantic smoke (EDGECACHE_SMOKE=1) =="
$WATCHDOG env EDGECACHE_SMOKE=1 cargo bench --bench semantic

if [ "${1:-}" != "--no-clippy" ]; then
    echo "== cargo clippy -q -- -D warnings =="
    if cargo clippy --version >/dev/null 2>&1; then
        cargo clippy -q -- -D warnings
    else
        echo "!! WARNING: clippy unavailable — lints NOT checked !!" >&2
    fi
fi

echo "check: OK"
