#!/usr/bin/env sh
# Mechanical gate for the rust/ crate: build, test, lint.  Run before every
# PR — the hot-path refactors (zero-copy blob pipeline, range transfers)
# regress silently without it.
#
# Usage: scripts/check.sh [--no-clippy]
set -eu

cd "$(dirname "$0")/../rust"

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

if [ "${1:-}" != "--no-clippy" ]; then
    echo "== cargo clippy -- -D warnings =="
    cargo clippy -- -D warnings
fi

echo "check: OK"
