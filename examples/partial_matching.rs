//! Partial matching walkthrough (paper §3.2, Figure 3, Table 4).
//!
//! Builds the astronomy N=5 prompt, registers its four nested ranges, then
//! issues crafted queries that land in each of the five cases and shows the
//! matched-token count and decode-time saving per case.
//!
//! ```bash
//! cargo run --release --example partial_matching
//! ```

use std::sync::Arc;

use edgecache::coordinator::{CacheBox, EdgeClient, EdgeClientConfig};
use edgecache::engine::Engine;
use edgecache::report::ascii_table;
use edgecache::workload::{Generator, Prompt};

fn main() -> anyhow::Result<()> {
    edgecache::util::logger::init_from_env();
    let preset = std::env::var("EDGECACHE_PRESET").unwrap_or_else(|_| "tiny".into());

    let cache_box = CacheBox::start_local()?;
    let engine = Arc::new(Engine::load_preset(&preset)?);
    let mut cfg = EdgeClientConfig::native(Some(cache_box.addr()));
    cfg.max_new_tokens = Some(2);
    let mut client = EdgeClient::new(Arc::clone(&engine), cfg)?;

    // the Figure-3 prompt: instruction + five examples + target question
    let gen = Generator::new(42);
    // N=5 like the paper for the full-size presets; the tiny demo preset has
    // a coarser (budget-capped) tokenizer, so N=2 keeps prompts inside its
    // context window without truncation muddying the case boundaries.
    let shots: usize = std::env::var("EDGECACHE_SHOTS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(if preset == "tiny" { 2 } else { 5 });
    let seed_prompt = gen.prompt("astronomy", 0, shots);
    let ranges = seed_prompt.prefix_texts();
    println!("prompt structure (chars): instruction {} | +ex1 {} | +all {} | full {}",
        ranges[0].len(), ranges[1].len(), ranges[2].len(), ranges[3].len());

    // Case-crafting: each query shares a successively longer prefix with the
    // seed prompt.  A fresh question in the same domain shares the
    // instruction+examples (Case 4); the same question repeats fully (Case 5);
    // a different domain shares nothing (Case 1).
    // Cases 2 and 3 are crafted by perturbing the examples after the shared
    // prefix (same instruction, different examples ⇒ only range 1 matches).
    let case2 = Prompt {
        // same instruction, alien examples → only the instruction range hits
        examples: gen.prompt("astronomy", 0, 0).examples.clone().into_iter().collect::<Vec<_>>(),
        target: gen.prompt("virology", 7, 0).target.clone(),
        ..seed_prompt.clone()
    };
    let case3 = Prompt {
        // instruction + first example intact, later examples replaced
        examples: {
            let mut e = seed_prompt.examples.clone();
            let other = gen.prompt("astronomy", 99, shots);
            let _ = other;
            // replace from the 2nd example on with shuffled copies of ex1
            for x in e.iter_mut().skip(1) {
                *x = seed_prompt.examples[0].replace("Answer", "ANSWER");
            }
            e
        },
        ..seed_prompt.clone()
    };
    let case4 = gen.prompt("astronomy", 1, shots); // same domain, new question
    let case5 = seed_prompt.clone();
    let case1 = gen.prompt("world_religions", 3, shots); // untouched domain

    // 1. seed the cache (miss + upload of all four ranges)
    let r0 = client.query(&seed_prompt)?;
    println!(
        "\nseed query: case {} — uploaded {:.2} MB across {} ranges\n",
        r0.case.number(),
        r0.uploaded_bytes as f64 / 1e6,
        4
    );

    // 2. replay the five cases
    let mut rows = Vec::new();
    for (label, p) in [
        ("Case 1 (no hit)", &case1),
        ("Case 2 (instruction)", &case2),
        ("Case 3 (instr+ex1)", &case3),
        ("Case 4 (instr+all ex)", &case4),
        ("Case 5 (full)", &case5),
    ] {
        let r = client.query(p)?;
        rows.push(vec![
            label.to_string(),
            r.case.number().to_string(),
            r.matched_tokens.to_string(),
            format!("{:.2}", r.matched_tokens as f64 / r.prompt_tokens as f64 * 100.0),
            format!("{:.2}", r.breakdown.t_decode().as_secs_f64() * 1e3),
            format!("{:.2}", r.breakdown.ttft().as_secs_f64() * 1e3),
        ]);
    }
    println!(
        "{}",
        ascii_table(
            &["Query", "Landed case", "# matched", "% matched", "T-decode [ms]", "TTFT [ms]"],
            &rows
        )
    );
    println!("(compare the shape against paper Table 4: decode time falls as the\n matched prefix grows; Cases 4/5 dominate the saving)");

    client.shutdown();
    cache_box.shutdown();
    Ok(())
}
