//! Catalog mechanics demo (paper §3.1, §3.3, Figure 2):
//!
//! 1. asynchronous master→local catalog delta sync between two clients;
//! 2. the communication saved by the local catalog (misses cost 0 network
//!    round trips vs 1+ for server probing);
//! 3. Bloom false positives: a poisoned catalog triggers a wasted download
//!    that is detected and falls back to local prefill — correctness intact.
//!
//! ```bash
//! cargo run --release --example catalog_sync
//! ```

use std::sync::Arc;
use std::time::Duration;

use edgecache::bloom::BloomFilter;
use edgecache::coordinator::{CacheBox, EdgeClient, EdgeClientConfig};
use edgecache::engine::Engine;
use edgecache::workload::Generator;

fn main() -> anyhow::Result<()> {
    edgecache::util::logger::init_from_env();
    let preset = std::env::var("EDGECACHE_PRESET").unwrap_or_else(|_| "tiny".into());

    println!("== the catalog data structure ==");
    let bloom = BloomFilter::paper_default();
    println!(
        "paper config: capacity 1M, fp 1% -> {:.2} MB bitmap, k={} hashes",
        bloom.size_bytes() as f64 / 1e6,
        bloom.k()
    );

    let cache_box = CacheBox::start_local()?;
    let engine = Arc::new(Engine::load_preset(&preset)?);
    let mk = |name: &str, sync_ms: u64| {
        let mut cfg = EdgeClientConfig::native(Some(cache_box.addr()));
        cfg.name = name.into();
        cfg.max_new_tokens = Some(2);
        cfg.sync_interval = Some(Duration::from_millis(sync_ms));
        cfg
    };
    let mut alice = EdgeClient::new(Arc::clone(&engine), mk("alice", 50))?;
    let mut bob = EdgeClient::new(Arc::clone(&engine), mk("bob", 50))?;

    let gen = Generator::new(7);
    let prompt = gen.prompt("philosophy", 0, 1);

    println!("\n== 1. async catalog sync ==");
    let r = alice.query(&prompt)?;
    println!(
        "alice: case {} (miss), uploaded {:.2} MB, registered ranges on the master",
        r.case.number(),
        r.uploaded_bytes as f64 / 1e6
    );
    println!("master catalog version: {}", cache_box.catalog_version());

    // bob's background sync loop picks the keys up without bob doing anything
    let t0 = std::time::Instant::now();
    loop {
        let v = bob.catalog.lock().unwrap().synced_version;
        if v >= cache_box.catalog_version() {
            break;
        }
        assert!(t0.elapsed() < Duration::from_secs(5), "sync too slow");
        std::thread::sleep(Duration::from_millis(10));
    }
    println!("bob's local catalog synced in {:?} (background thread)", t0.elapsed());

    let r = bob.query(&prompt)?;
    println!("bob:   case {} — full hit via synced catalog", r.case.number());
    assert_eq!(r.case.number(), 5);

    println!("\n== 2. what the catalog saves ==");
    let miss_prompt = gen.prompt("jurisprudence", 0, 1);
    let before = bob.stats.false_positives;
    let r = bob.query(&miss_prompt)?;
    println!(
        "miss with catalog: Bloom {:.3} ms of local work, Redis {:.3} ms (no probe round trips)",
        r.breakdown.get(edgecache::metrics::Phase::Bloom).as_secs_f64() * 1e3,
        r.breakdown.get(edgecache::metrics::Phase::Redis).as_secs_f64() * 1e3,
    );
    assert_eq!(bob.stats.false_positives, before);

    println!("\n== 3. false positives are safe ==");
    let fp_prompt = gen.prompt("moral_disputes", 0, 1);
    {
        // poison alice's catalog: mark all ranges of an *uncached* prompt
        let tokens = engine.tokenize_prompt(&fp_prompt.full_text());
        let meta = edgecache::catalog::ModelMeta::new(engine.model_hash());
        let ranges =
            edgecache::catalog::ranges_for(&meta, &tokens, &[tokens.len() / 2, tokens.len()]);
        alice.catalog.lock().unwrap().register(&ranges);
    }
    let r = alice.query(&fp_prompt)?;
    println!(
        "poisoned lookup: false_positive={} case={} — wasted GET, then local prefill; output intact ({} tokens)",
        r.false_positive,
        r.case.number(),
        r.response_tokens.len()
    );
    assert!(r.false_positive);
    assert_eq!(r.case.number(), 1);

    println!("\nexpected FP cost at design rate: 0.01 x download time (paper §5.2.4)");
    alice.shutdown();
    bob.shutdown();
    cache_box.shutdown();
    println!("OK");
    Ok(())
}
