//! Quickstart: one cache box + one edge client, miss → hit.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```
//!
//! Demonstrates the paper's core effect on a single prompt: the first query
//! prefill-decodes locally and uploads its KV state; the second query finds
//! the state through the local Bloom catalog, downloads it, and skips
//! prefill entirely — TTFT collapses.

use std::sync::Arc;

use edgecache::coordinator::{CacheBox, EdgeClient, EdgeClientConfig};
use edgecache::engine::Engine;
use edgecache::workload::Generator;

fn main() -> anyhow::Result<()> {
    edgecache::util::logger::init_from_env();
    let preset = std::env::var("EDGECACHE_PRESET").unwrap_or_else(|_| "tiny".into());

    // 1. the cache box (Figure 1, middle node) — in-process for the demo
    let cache_box = CacheBox::start_local()?;
    println!("cache box listening on {}", cache_box.addr());

    // 2. an edge client running the local LLM
    let engine = Arc::new(Engine::load_preset(&preset)?);
    let mut cfg = EdgeClientConfig::native(Some(cache_box.addr()));
    cfg.max_new_tokens = Some(8);
    let mut client = EdgeClient::new(engine, cfg)?;

    // 3. an MMLU-like prompt (astronomy, one few-shot example)
    let prompt = Generator::new(42).prompt("astronomy", 0, 1);
    println!(
        "\nprompt: {} words / domain {}\n",
        prompt.word_count(),
        prompt.domain
    );

    // 4. first query: cache miss — local prefill, then state upload
    let r1 = client.query(&prompt)?;
    println!(
        "query 1: case {} (miss)  TTFT {:>8.2} ms   uploaded {:.2} MB",
        r1.case.number(),
        r1.breakdown.ttft().as_secs_f64() * 1e3,
        r1.uploaded_bytes as f64 / 1e6
    );

    // 5. second query: full hit — download the state, skip prefill
    let r2 = client.query(&prompt)?;
    println!(
        "query 2: case {} (hit)   TTFT {:>8.2} ms   downloaded {:.2} MB",
        r2.case.number(),
        r2.breakdown.ttft().as_secs_f64() * 1e3,
        r2.downloaded_bytes as f64 / 1e6
    );

    assert_eq!(
        r1.response_tokens, r2.response_tokens,
        "cached path must produce identical output"
    );
    println!(
        "\nidentical responses: {:?}",
        &r2.response_text[..r2.response_text.len().min(60)]
    );
    println!(
        "breakdown (hit): {}",
        r2.breakdown
    );

    client.shutdown();
    cache_box.shutdown();
    Ok(())
}
