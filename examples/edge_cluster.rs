//! End-to-end driver (DESIGN.md §7): a real peer fabric of
//! `EDGECACHE_PEERS` cache-box TCP servers + two edge clients cooperating
//! over an MMLU-like multi-domain trace — the Figure-1 topology
//! generalised to N middle nodes, with the real model over PJRT, real
//! state bytes over real sockets, link shaping and (optionally) device
//! pacing.
//!
//! ```bash
//! cargo run --release --example edge_cluster                  # native speed
//! EDGECACHE_PACED=1 cargo run --release --example edge_cluster  # paper pacing
//! EDGECACHE_PRESET=edge-270m cargo run --release --example edge_cluster
//! EDGECACHE_PEERS=3 EDGECACHE_REPLICAS=1 cargo run --release --example edge_cluster
//! EDGECACHE_PLACEMENT=ring cargo run --release --example edge_cluster
//! ```
//!
//! Reports per-case TTFT/TTLT distributions, the cooperative-reuse effect
//! (client 2 benefiting from client 1's uploads) and — with several peers
//! — the placement spread across boxes plus each client's per-peer
//! ledger.  The run recorded in EXPERIMENTS.md §E2E used the defaults
//! below.

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Duration;

use edgecache::coordinator::{
    CacheBox, DeadlineBudget, EdgeClient, EdgeClientConfig, PeerConfig, PlacementKind, PlanMode,
};
use edgecache::devicemodel::DeviceProfile;
use edgecache::engine::Engine;
use edgecache::metrics::CaseAggregate;
use edgecache::netsim::LinkModel;
use edgecache::report::ascii_table;
use edgecache::workload::{Generator, Trace};

fn main() -> anyhow::Result<()> {
    edgecache::util::logger::init_from_env();
    let preset = std::env::var("EDGECACHE_PRESET").unwrap_or_else(|_| "tiny".into());
    let paced = std::env::var("EDGECACHE_PACED").is_ok();
    let n_domains: usize = std::env::var("EDGECACHE_DOMAINS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(6);
    let per_domain: usize = std::env::var("EDGECACHE_PER_DOMAIN")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(4);
    let n_peers: usize = std::env::var("EDGECACHE_PEERS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1)
        .max(1);
    let replicas: usize = std::env::var("EDGECACHE_REPLICAS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0);
    let placement = match std::env::var("EDGECACHE_PLACEMENT") {
        Ok(v) => PlacementKind::by_name(&v)
            .unwrap_or_else(|| panic!("EDGECACHE_PLACEMENT={v}: expected p2c|ring")),
        Err(_) => PlacementKind::PowerOfTwoChoices,
    };

    println!("== edgecache end-to-end cluster ==");
    println!(
        "preset={preset} paced={paced} domains={n_domains} per_domain={per_domain} \
         peers={n_peers} replicas={replicas} placement={}",
        placement.name()
    );

    // the peer fabric: N cache boxes on real TCP sockets
    let cache_boxes: Vec<CacheBox> = (0..n_peers)
        .map(|_| CacheBox::start_local())
        .collect::<anyhow::Result<_>>()?;
    for (i, cb) in cache_boxes.iter().enumerate() {
        println!("cache box {i}: {}", cb.addr());
    }

    // one engine (model artifacts) shared by both client processes' logic;
    // each client gets its own connections, catalogs, shapers and pacer
    let t0 = std::time::Instant::now();
    let engine = Arc::new(Engine::load_preset(&preset)?);
    println!(
        "engine loaded in {:.2}s ({:.1} MB params)",
        t0.elapsed().as_secs_f64(),
        engine.model.param_bytes as f64 / 1e6
    );

    let peers: Vec<PeerConfig> = cache_boxes
        .iter()
        .map(|cb| PeerConfig::new(cb.addr()))
        .collect();
    let mk_cfg = |name: &str, seed: u64| EdgeClientConfig {
        name: name.to_string(),
        peers: peers.clone(),
        replicas,
        placement,
        link: if paced { LinkModel::wifi4_2g4() } else { LinkModel::loopback() },
        device: if paced { DeviceProfile::pi_zero_2w() } else { DeviceProfile::host() },
        max_new_tokens: Some(if paced { 4 } else { 8 }),
        compression: edgecache::model::state::Compression::None,
        chunk_tokens: edgecache::model::state::DEFAULT_CHUNK_TOKENS,
        adaptive_chunk: false,
        partial_matching: true,
        use_catalog: true,
        fetch_policy: edgecache::coordinator::FetchPolicy::Always,
        // chunk planning only engages under device pacing (the host
        // profile models no recompute rate, so unpaced runs all-fetch)
        plan: PlanMode::Chunk,
        probe_negative_ttl: Duration::from_millis(1500),
        min_hit_tokens: 1,
        sync_interval: Some(Duration::from_millis(100)),
        // liveness on: a stalled box costs one 2 s op budget, never a hang
        deadline: Some(DeadlineBudget::default()),
        gossip: true,
        indirect_probes: 1,
        adaptive_deadline_k: 0.0,
        // the semantic tier rides along: sketches register with uploads and
        // sync between the clients, though this exact-repeat trace never
        // needs a probe (cooperative reuse lands as exact hits)
        semantic: true,
        semantic_dist: 16,
        semantic_k: 3,
        repair_sweep: Duration::ZERO,
        seed,
    };
    let mut clients = vec![
        EdgeClient::new(Arc::clone(&engine), mk_cfg("client-1", 1))?,
        EdgeClient::new(Arc::clone(&engine), mk_cfg("client-2", 2))?,
    ];

    // the workload trace: shared instruction+examples within each domain
    let gen = Generator::new(42);
    let trace = Trace::generate(42, clients.len(), n_domains, per_domain, 1);
    println!("trace: {} queries across {} domains\n", trace.queries.len(), n_domains);

    let mut by_case: BTreeMap<usize, CaseAggregate> = BTreeMap::new();
    let run0 = std::time::Instant::now();
    for (i, q) in trace.queries.iter().enumerate() {
        let c = &mut clients[q.client];
        let p = gen.prompt(&q.domain, q.question_index, q.n_shots);
        let r = c.query(&p)?;
        by_case.entry(r.case.number()).or_default().push(&r.breakdown);
        println!(
            "[{:>3}/{}] client-{} {:<28} case {}  ttft {:>9.2} ms  ttlt {:>9.2} ms  {}",
            i + 1,
            trace.queries.len(),
            q.client + 1,
            q.domain,
            r.case.number(),
            r.breakdown.ttft().as_secs_f64() * 1e3,
            r.breakdown.ttlt().as_secs_f64() * 1e3,
            if r.false_positive { "FP!" } else { "" }
        );
    }
    let wall = run0.elapsed();

    // ---- report ------------------------------------------------------------
    println!("\n== per-case latency (mean over trace) ==");
    let rows: Vec<Vec<String>> = by_case
        .iter()
        .map(|(case, a)| {
            vec![
                format!("Case {case}"),
                a.n.to_string(),
                format!("{:.3}", a.ttft.mean()),
                format!("{:.3}", a.ttft.percentile(0.95)),
                format!("{:.3}", a.ttlt.mean()),
                format!("{:.1}", a.mean_prompt_tokens()),
                format!("{:.2}", a.mean_state_mb()),
            ]
        })
        .collect();
    println!(
        "{}",
        ascii_table(
            &["Case", "n", "TTFT mean [s]", "TTFT p95 [s]", "TTLT mean [s]", "# tokens", "state MB"],
            &rows
        )
    );

    if let (Some(miss), Some(hit)) = (by_case.get(&1), by_case.get(&5)) {
        let red = (hit.ttft.mean() - miss.ttft.mean()) / miss.ttft.mean() * 100.0;
        println!(
            "TTFT full-hit vs miss: {:.3} s -> {:.3} s ({red:+.1} %)  [paper low-end: -93.12 %]",
            miss.ttft.mean(),
            hit.ttft.mean()
        );
    }
    let total_queries: u64 = clients.iter().map(|c| c.stats.queries).sum();
    let throughput = total_queries as f64 / wall.as_secs_f64();
    println!("\nwall time {:.1} s, {} queries, {:.2} q/s", wall.as_secs_f64(), total_queries, throughput);
    for c in &mut clients {
        c.refresh_stats();
        println!(
            "  {} [{}]: hits by case {:?}, FPs {}, down {:.2} MB, up {:.2} MB, \
             multi-source {}, re-plans {}, chunks {} fetched / {} recomputed \
             ({} mixed plans), fallback probes {} ({} hits, {} suppressed), \
             repairs {}, timeouts {}, suspects {}, heals {}, \
             busy rejections {} ({} free replans), \
             semantic {} probes / {} hits / {} false ({} tokens recovered)",
            c.cfg.name,
            c.placement_name(),
            c.stats.hits_by_case,
            c.stats.false_positives,
            c.stats.bytes_down as f64 / 1e6,
            c.stats.bytes_up as f64 / 1e6,
            c.stats.multi_source_fetches,
            c.stats.re_plans,
            c.stats.chunks_fetched,
            c.stats.chunks_recomputed,
            c.stats.plan_mixed,
            c.stats.fallback_probes,
            c.stats.fallback_probe_hits,
            c.stats.probes_suppressed,
            c.stats.repair_republishes,
            c.stats.timeouts,
            c.stats.suspect_transitions,
            c.stats.heals,
            c.stats.busy_rejections,
            c.stats.replans_on_busy,
            c.stats.semantic_probes,
            c.stats.semantic_hits,
            c.stats.semantic_false_probes,
            c.stats.semantic_tokens_recovered,
        );
        for l in c.peer_ledgers() {
            println!(
                "    peer {}: down {:.2} MB, up {:.2} MB, shares {} ({} failed, \
                 {} chunks), uploads {} (+{} replicas), placed {}, probes {}, \
                 repairs {}, {} sync rounds, {} heartbeats, {} heals, {} timeouts, \
                 {} sheds, peak pending {}, {} sketch entries \
                 ({} sections synced)",
                l.addr,
                l.bytes_down as f64 / 1e6,
                l.bytes_up as f64 / 1e6,
                l.fetch_shares,
                l.share_failures,
                l.chunks_served,
                l.uploads,
                l.replica_uploads,
                l.placed_entries,
                l.fallback_probes,
                l.repair_republishes,
                l.sync_rounds,
                l.heartbeats,
                l.heals,
                l.timeouts,
                l.sheds,
                l.peak_pending,
                l.sketch_entries,
                l.sketch_sections,
            );
        }
    }
    for (i, cb) in cache_boxes.iter().enumerate() {
        let (keys, bytes, evictions) = cb.stats();
        println!(
            "  cache box {i}: {keys} states, {:.2} MB, {evictions} evictions",
            bytes as f64 / 1e6
        );
    }

    // cooperative reuse must actually have happened
    let cross_hits: u64 = clients
        .iter()
        .map(|c| c.stats.hits_by_case[1..].iter().sum::<u64>())
        .sum();
    assert!(cross_hits > 0, "expected at least one cache hit in the trace");
    // with several peers, placement must actually spread entries around
    if n_peers > 1 {
        let populated = cache_boxes
            .iter()
            .filter(|cb| cb.stats().0 > 0)
            .count();
        assert!(
            populated > 1,
            "placement policy must use more than one box ({populated}/{n_peers})"
        );
    }

    for c in clients {
        c.shutdown();
    }
    for cb in cache_boxes {
        cb.shutdown();
    }
    println!("\nOK");
    Ok(())
}
